"""WASAP-SGD (paper Algorithm 1) — device-resident SPMD/TPU adaptation.

Phase 1 (paper: async parameter server) → **local SGD with periodic sparse
model averaging**: K workers take H local momentum-SGD steps on their data
shards, then weights (and momentum) are averaged. H>1 reproduces asynchrony's
communication-avoidance and staleness; H=1 with the Goyal warmup/linear-
scaling schedule is exactly the paper's synchronous control, WASSP-SGD.

Phase 1 runs on the device-resident substrate (DESIGN.md §4): ONE jitted,
buffer-donated call per epoch ``lax.scan``s over the sync rounds — an inner
scan over the H local steps per worker, then an on-device pytree average
between rounds. The training set lives on the device; the host ships only
each worker-shard's epoch index permutation (``ShardedLoader.epoch_order``),
per-step learning rates, and validity weights (tail rounds are padded to a
static H so one compile serves the whole run). The worker axis is expressed
two interchangeable ways, selected by ``WASAPConfig.worker_axis``:

* ``"vmap"``   — stacked (K, ...) worker axis on one device (CPU tests).
* ``"shard_map"`` — the same program shard_map'd over the 'data' axis of a
  ``launch.mesh.make_worker_mesh`` mesh, each shard vmapping its local
  workers and averaging after an ``all_gather`` over the axis (the
  deterministic-order equivalent of a pmean) — bit-identical to the vmap
  path, and the per-shard program a pod runs.

The master's topology evolution between epochs runs jitted on fixed-capacity
arrays (``core.topology.evolve_element_layers_device``) — zero recompiles,
zero host<->device parameter traffic for the whole phase. Every worker
update is implicitly `RetainValidUpdates`-filtered because values are
re-aligned to the evolved topology before workers resume (the paper's
Algorithm 1 line 14).

Phase 2: workers train **locally** on the fused epoch segments
(``train.trainer.make_segment_fn``) and evolve their own topologies
independently on device (per-worker PRNG streams); at the end the K sparse
models are averaged over the union of their topologies and re-sparsified to
the target connection count by the paper's sign-aware magnitude rule
(Algorithm 1, line 37).

``WASAPConfig.fused=False`` keeps the seed-era round loop — per-round Python
dispatch, host-side replication, numpy batch stacking, host evolution — as
the measured baseline for ``benchmarks/table3_parallel.py``.
"""
from __future__ import annotations

import dataclasses
import functools
import time
import warnings
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core.sparsity import ElementTopology
from repro.core.topology import (
    evolve_element,
    evolve_element_layers_device,
    prune_indices_by_magnitude,
)
from repro.data.loader import ShardedLoader
from repro.data.synthetic import Dataset
from repro.launch.mesh import make_worker_mesh
from repro.launch.steps import make_mlp_step_core, scan_masked_segment
from repro.models.mlp import (
    SparseMLP,
    SparseMLPConfig,
    cross_entropy_loss,
    mlp_forward,
)
from repro.optim.sgd import MomentumSGD, SGDState, replace_values_velocity
from repro.runtime import donation
from repro.runtime.supervisor import retry_step
from repro.train.trainer import evaluate, make_segment_fn, make_step_fn
from repro import obs
from repro.obs import probes

__all__ = [
    "WASAPConfig",
    "WASAPTrainer",
    "make_phase1_epoch_fn",
    "sparse_average_and_resparsify",
]


@dataclasses.dataclass
class WASAPConfig:
    n_workers: int = 4
    phase1_epochs: int = 6
    phase2_epochs: int = 2
    sync_every: int = 4          # H — local steps between averages (1 => WASSP)
    lr: float = 0.01
    lr_boost: float = 2.0        # paper §2.3: larger LR early in async phase
    lr_boost_epochs: int = 2
    warmup_steps: int = 50       # WASSP: Goyal et al. gradual warmup
    momentum: float = 0.9
    weight_decay: float = 2e-4
    zeta: float = 0.3
    mode: str = "wasap"          # wasap | wassp
    seed: int = 0
    batch_size: int = 32
    average_momentum: bool = True
    fused: bool = True           # one jitted call per epoch (False: seed loop)
    worker_axis: str = "vmap"    # vmap | shard_map
    probe: bool = False          # training-dynamics probes (obs.probes, §12)


# ---------------------------------------------------------------------------
# device-side worker programs
# ---------------------------------------------------------------------------


def _average_pytree(stacked, weights=None):
    if weights is None:
        return jax.tree.map(lambda a: a.mean(axis=0), stacked)
    w = weights / weights.sum()

    def wavg(a):
        wb = w.reshape((-1,) + (1,) * (a.ndim - 1))
        return (a * wb).sum(axis=0)

    return jax.tree.map(wavg, stacked)


def _cast_like(tree, ref):
    """Restore the reference dtypes after an averaging reduction (mean
    promotes the int32 step counter to float; scan carries and repeated jit
    calls both need dtype-stable state)."""
    return jax.tree.map(lambda a, r: a.astype(r.dtype), tree, ref)


_average_workers = jax.jit(_average_pytree)


def _replicate(tree, k: int):
    return jax.tree.map(lambda a: jnp.broadcast_to(a, (k,) + a.shape), tree)


def _take_worker0(tree):
    return jax.tree.map(lambda a: a[0], tree)


def make_phase1_epoch_fn(
    config: SparseMLPConfig,
    opt: MomentumSGD,
    *,
    n_workers: int,
    average_momentum: bool = True,
    worker_axis: str = "vmap",
    mesh=None,
    weighted: bool = False,
    donate=None,
    probe: bool = False,
):
    """Build the jitted phase-1 epoch: one device call scanning sync rounds.

    ``epoch_fn(params, opt_state, topo, x_all, y_all, idx, lrs, valid, keys)``

    * ``idx``   — (R, K, H, B) int32 sample indices into the device-resident
      ``x_all``/``y_all`` (each worker-shard's ``ShardedLoader.epoch_order``,
      padded to R*H steps);
    * ``lrs``/``valid`` — (R, H) per-step learning rates and validity
      weights (0 on padded tail steps: those steps trace but leave the
      carry untouched, so the tail round never changes a shape);
    * ``keys``  — (R, K, 2) per-round per-worker PRNG keys (dropout).

    Returns ``(params, opt_state, loss_sums)`` with ``loss_sums`` the (R,)
    per-round sums of valid per-step losses.

    ``weighted=True`` appends a tenth argument ``worker_w`` — (K,) validity
    weights over the worker axis, renormalized inside the average — so an
    evicted/dead worker contributes zero while the round completes with the
    survivors (the elastic WASAP round, DESIGN.md §8). With uniform weights
    the result is bit-identical to the unweighted build only up to float
    reassociation, so the unweighted path stays the default.

    ``worker_axis="vmap"`` stacks the K workers on one device;
    ``"shard_map"`` maps the same program over the 'data' axis of ``mesh``
    (each shard vmaps its K/D local workers, all_gathers the worker axis,
    and averages in the same order as the vmap path — bit-identical).

    ``donate`` overrides the central donation policy
    (``repro.runtime.donation``) — the contract auditor passes explicit
    argnums to force-build donated/undonated variants.

    ``probe=True`` (static — the default build's traced program is exactly
    the pre-probe program) appends a fourth output: the per-layer
    training-dynamics stats of ``obs.probes.segment_probe``, computed on
    the epoch's first batch (round 0, worker 0 — always a valid, unpadded
    step) AFTER the sync-round scan. Stats stay on device; the trainer
    records them host-side after its ``block_on`` (DESIGN.md §12).
    """
    if worker_axis not in ("vmap", "shard_map"):
        raise ValueError(f"worker_axis must be vmap|shard_map, got {worker_axis!r}")
    if worker_axis == "shard_map":
        if mesh is None:
            raise ValueError("worker_axis='shard_map' needs a mesh")
        data_size = dict(zip(mesh.axis_names, mesh.devices.shape))["data"]
        if n_workers % data_size != 0:
            raise ValueError(
                f"n_workers={n_workers} must be divisible by the mesh's "
                f"data axis ({data_size})"
            )
        k_local = n_workers // data_size
    else:
        k_local = n_workers

    def local_steps(params, opt_state, topo, x_all, y_all, idx_h, lrs_h, valid_h, key):
        step_core = make_mlp_step_core(config, opt, topo, x_all, y_all)
        params, opt_state, _, losses = scan_masked_segment(
            step_core, params, opt_state, key, (idx_h, lrs_h), valid_h
        )
        return params, opt_state, losses.sum()

    def epoch_program(
        params, opt_state, topo, x_all, y_all, idx, lrs, valid, keys,
        worker_w=None,
    ):
        def round_body(carry, inp):
            params, opt_state = carry
            idx_r, lrs_r, valid_r, keys_r = inp
            sp = _replicate(params, k_local)
            so = _replicate(opt_state, k_local)
            sp, so, lsum = jax.vmap(
                lambda p, s, i, kk: local_steps(
                    p, s, topo, x_all, y_all, i, lrs_r, valid_r, kk
                )
            )(sp, so, idx_r, keys_r)
            if worker_axis == "shard_map":
                # gather the full worker axis so every shard averages the K
                # results in the same order as the vmap path — the
                # deterministic-order equivalent of a pmean
                sp, so, lsum = jax.tree.map(
                    lambda a: jax.lax.all_gather(a, "data", axis=0, tiled=True),
                    (sp, so, lsum),
                )
            new_params = _cast_like(_average_pytree(sp, worker_w), params)
            new_opt = (
                _cast_like(_average_pytree(so, worker_w), opt_state)
                if average_momentum
                else _take_worker0(so)
            )
            return (new_params, new_opt), lsum.sum()

        (params, opt_state), loss_sums = jax.lax.scan(
            round_body, (params, opt_state), (idx, lrs, valid, keys)
        )
        if not probe:
            return params, opt_state, loss_sums
        # post-scan probe on the epoch's first batch (round 0, worker 0 —
        # always valid; padding only reaches tail rounds)
        xb = jnp.take(x_all, idx[0, 0, 0], axis=0, mode="clip")
        yb = jnp.take(y_all, idx[0, 0, 0], axis=0, mode="clip")

        def probe_loss(p):
            logits, preacts = mlp_forward(
                p, topo, xb, config, train=False, return_preacts=True
            )
            return cross_entropy_loss(logits, yb), preacts

        (_, preacts), grads = jax.value_and_grad(probe_loss, has_aux=True)(
            params
        )
        stats = probes.segment_probe(
            params, grads, topo, preacts, config.layer_dims
        )
        return params, opt_state, loss_sums, stats

    if not weighted:
        # keep the historical 9-arg signature (and its exact averaging
        # program) when no elastic weights are in play
        program = functools.partial(epoch_program, worker_w=None)
    else:
        program = epoch_program

    fn = program
    if worker_axis == "shard_map":
        in_specs = [
            P(), P(), P(), P(), P(),          # params/opt/topo/x/y replicated
            P(None, "data"),                  # idx   (R, K, H, B) on axis 1
            P(), P(),                         # lrs/valid replicated
            P(None, "data"),                  # keys  (R, K, 2)   on axis 1
        ]
        if weighted:
            in_specs.append(P())              # worker_w (K,) replicated
        out_specs = (P(), P(), P(), P()) if probe else (P(), P(), P())
        fn = shard_map(
            program,
            mesh=mesh,
            in_specs=tuple(in_specs),
            out_specs=out_specs,  # P() prefixes the probe-stats dict leaves
            check_rep=False,  # all_gather + mean makes every output replicated
        )
    return jax.jit(fn, donate_argnums=donation.donate_argnums(0, 1, override=donate))


def _make_worker_round(config: SparseMLPConfig, opt: MomentumSGD):
    """Seed-era round: each worker runs H local steps over stacked batches.

    Kept as the measured baseline for the fused epoch (per-round Python
    dispatch, host-side replication, numpy batch stacking). Tail rounds are
    padded to a static H with ``valid`` weights so one compile serves the
    whole run, and the dropout key plumbing is explicit: each step splits a
    fresh ``sub`` that the loss closes over (the seed closed over the
    rebound parent key by late binding and never used its split).
    """

    @jax.jit
    def worker_round(stacked_params, stacked_opt, topo, xs, ys, lrs, valid, rngs):
        # xs: (K, H, B, F); ys: (K, H, B); lrs/valid: (H,)
        step_core = make_mlp_step_core(config, opt, topo)

        def per_worker(params, opt_state, x_h, y_h, rng):
            params, opt_state, _, losses = scan_masked_segment(
                step_core, params, opt_state, rng, (x_h, y_h, lrs), valid
            )
            return params, opt_state, losses.sum()

        return jax.vmap(per_worker)(stacked_params, stacked_opt, xs, ys, rngs)

    return worker_round


# ---------------------------------------------------------------------------
# final merge (Algorithm 1, line 37)
# ---------------------------------------------------------------------------


def _sign_aware_drop(avg: np.ndarray, surplus: int) -> np.ndarray:
    """Indices of ``surplus`` connections to drop by the paper's sign-aware
    magnitude rule: exact zeros first, then each sign's proportional
    low-magnitude tail (the smallest positives and the largest negatives,
    via :func:`prune_indices_by_magnitude`), with any integer remainder
    topped up from the smallest remaining ``|avg|``."""
    zeros = np.flatnonzero(avg == 0)
    if zeros.size >= surplus:
        return zeros[:surplus]
    n_signed = int((avg > 0).sum() + (avg < 0).sum())
    zeta = (surplus - zeros.size) / n_signed
    drop = prune_indices_by_magnitude(avg, zeta)  # zeros + per-sign tails
    short = surplus - drop.size  # >= 0: per-sign tail sizes are floored
    if short > 0:
        rest = np.setdiff1d(np.arange(avg.size), drop)
        rest = rest[np.argsort(np.abs(avg[rest]), kind="stable")]
        drop = np.concatenate([drop, rest[:short]])
    return drop


def sparse_average_and_resparsify(
    topos: List[ElementTopology],
    values: List[np.ndarray],
    target_nnz: int,
) -> Tuple[ElementTopology, np.ndarray]:
    """Average K sparse models over the union of their topologies, then keep
    ``target_nnz`` connections by the paper's sign-aware magnitude rule
    (Algorithm 1 line 37): the surplus is pruned as exact zeros, the
    smallest-positive tail and the largest-negative tail — each sign
    contributing its proportional share — not a plain |value| ranking."""
    k = len(topos)
    assert k >= 1
    in_dim, out_dim = topos[0].in_dim, topos[0].out_dim
    flat_all = np.concatenate(
        [t.rows.astype(np.int64) * out_dim + t.cols for t in topos]
    )
    val_all = np.concatenate([np.asarray(v, np.float64) for v in values])
    uniq, inv = np.unique(flat_all, return_inverse=True)
    summed = np.zeros(uniq.size, np.float64)
    np.add.at(summed, inv, val_all)
    avg = (summed / k).astype(np.float32)  # absent connections count as zero

    surplus = uniq.size - int(target_nnz)
    if surplus > 0:
        # surplus = S' - S unimportant connections pruned (Algorithm 1 l.37)
        drop = _sign_aware_drop(avg, surplus)
        keep = np.setdiff1d(np.arange(uniq.size), drop)
    else:
        keep = np.arange(uniq.size)
    rows = (uniq[keep] // out_dim).astype(np.int32)
    cols = (uniq[keep] % out_dim).astype(np.int32)
    topo = ElementTopology(in_dim, out_dim, rows, cols)
    order = np.lexsort((rows, cols))
    return topo, avg[keep][order]


# ---------------------------------------------------------------------------
# trainer
# ---------------------------------------------------------------------------


class WASAPTrainer:
    """Two-phase WASAP/WASSP-SGD for SET-MLPs (element sparsity)."""

    def __init__(self, model: SparseMLP, data: Dataset, wc: WASAPConfig):
        assert model.config.impl == "element", "WASAP path uses element sparsity"
        self.model = model
        self.data = data
        self.wc = wc
        self.opt = MomentumSGD(momentum=wc.momentum, weight_decay=wc.weight_decay)
        self.rng = np.random.default_rng(wc.seed)
        self.key = jax.random.PRNGKey(wc.seed)
        cfg = model.config
        # the device paths encode flat positions in int32
        self._device_ok = all(
            cfg.layer_dims[l] * cfg.layer_dims[l + 1] < 2**31
            for l in range(cfg.n_layers)
        )
        if not self._device_ok:
            if wc.worker_axis == "shard_map":
                raise ValueError(
                    "worker_axis='shard_map' needs the device-resident path, "
                    "but a layer's in_dim*out_dim exceeds int32"
                )
            if wc.fused:
                warnings.warn(
                    "fused WASAP needs in_dim*out_dim < 2**31 per layer; "
                    "falling back to the seed round loop",
                    stacklevel=2,
                )
        self._fused = wc.fused and self._device_ok
        self._h = 1 if wc.mode == "wassp" else wc.sync_every
        self._mesh = None
        if self._fused:
            self._mesh = (
                make_worker_mesh(wc.n_workers)
                if wc.worker_axis == "shard_map"
                else None
            )
            self._epoch_fn = make_phase1_epoch_fn(
                cfg, self.opt,
                n_workers=wc.n_workers,
                average_momentum=wc.average_momentum,
                worker_axis=wc.worker_axis,
                mesh=self._mesh,
                probe=wc.probe,
            )
            self._segment = make_segment_fn(cfg, self.opt)
            # phase-2 probe segment for worker 0 only (never pass an
            # explicit False — the 2-arg call is the shared cache key)
            self._probe_segment = (
                make_segment_fn(cfg, self.opt, True) if wc.probe else None
            )
        else:
            self._round = _make_worker_round(cfg, self.opt)
        self.loaders = [
            ShardedLoader(
                data.x_train, data.y_train, wc.batch_size,
                seed=wc.seed, shard_id=k, num_shards=wc.n_workers,
            )
            for k in range(wc.n_workers)
        ]
        self.history: Dict[str, list] = {
            "epoch": [], "phase": [], "test_acc": [], "train_loss": [],
            "n_params": [], "epoch_seconds": [],
        }
        self._device_data = None  # lazy: one upload shared by both phases
        # -- resume / elastic surface (DESIGN.md §8), fused path -------------
        self.start_epoch = 0            # absolute epoch run() continues from
        self.epoch_next = 0
        self.fault_hook = None          # hook(gstep) before each epoch call
        self.epoch_end_hook = None      # hook(trainer, epoch) at boundaries
        self.step_retries = 0
        self.retry_backoff_s = 0.0
        # heartbeat-driven elasticity: attach a supervisor.
        # HeartbeatMonitor over worker ids "w0".."w{K-1}" (plus an optional
        # beat_filter(worker_id, epoch) -> bool, e.g. faultinject.
        # StragglerInjector.beats) and phase-1 rounds run with renormalized
        # validity weights: evicted/dead workers contribute zero.
        self.monitor = None
        self.beat_filter = None
        self.elastic_log: List[Dict] = []
        self._phase = 1                 # 1 | 2 — which phase run() enters
        self._p1_state = None           # (params, opt_state, topo) boundary
        self._p2_workers = None         # phase-2 replicas at a boundary
        self._epoch_fn_weighted = None  # built lazily when a monitor attaches
        self._last_churn = None         # (n_pruned, nnz) from master evolve

    def _data_on_device(self):
        if self._device_data is None:
            self._device_data = (
                jnp.asarray(self.data.x_train), jnp.asarray(self.data.y_train)
            )
        return self._device_data

    # -- lr schedules --------------------------------------------------------

    def _lr(self, gstep: int, epoch: int) -> float:
        wc = self.wc
        if wc.mode == "wassp":
            # gradual warmup + linear scaling rule (Goyal et al. 2017)
            target = wc.lr * wc.n_workers
            frac = min(1.0, (gstep + 1) / max(1, wc.warmup_steps))
            return wc.lr + frac * (target - wc.lr)
        # wasap: larger LR for the first few epochs, then fixed (paper §2.3)
        return wc.lr * wc.lr_boost if epoch < wc.lr_boost_epochs else wc.lr

    # -- phases --------------------------------------------------------------

    def run(self) -> Dict[str, list]:
        with obs.span(
            "wasap.run", mode=self.wc.mode, workers=self.wc.n_workers,
            fused=self._fused, worker_axis=self.wc.worker_axis,
        ):
            if self._fused:
                if self._phase == 1:
                    self._run_phase1_fused()
                    self._phase = 2
                worker_states = self._run_phase2_fused()
            else:
                self._run_phase1_roundloop()
                worker_states = self._run_phase2_perbatch()
            with obs.span("wasap.merge", workers=len(worker_states)):
                self._merge_workers(worker_states)
        acc = evaluate(self.model, self.data.x_test, self.data.y_test)
        wc = self.wc
        self.history["epoch"].append(wc.phase1_epochs + wc.phase2_epochs)
        self.history["phase"].append("final")
        self.history["train_loss"].append(float("nan"))
        self.history["test_acc"].append(acc)
        self.history["n_params"].append(self.model.n_params)
        self.history["epoch_seconds"].append(0.0)
        return self.history

    # -- phase 1: local SGD + periodic averaging (device-resident) -----------

    def _run_phase1_fused(self) -> None:
        wc, model = self.wc, self.model
        k, h, bsz = wc.n_workers, self._h, wc.batch_size
        steps = min(ld.steps_per_epoch for ld in self.loaders)
        if steps == 0:
            raise ValueError("batch_size larger than the worker shards")
        rounds = -(-steps // h)
        padded = rounds * h
        x_all, y_all = self._data_on_device()
        if self._p1_state is not None:  # resumed at an epoch boundary
            params, opt_state, topo = self._p1_state
        else:
            params = model.params()
            opt_state = self.opt.init(params)
            topo = model.topo_arrays()
        start = min(self.start_epoch, wc.phase1_epochs)
        gstep = start * steps
        for epoch in range(start, wc.phase1_epochs):
            with obs.span(
                "wasap.epoch", epoch=epoch, phase=1, rounds=rounds
            ) as ep_sp:
                t0 = time.perf_counter()
                weights = (
                    self._worker_weights(epoch)
                    if self.monitor is not None else None
                )
                idx = np.zeros((rounds, k, h, bsz), np.int32)
                for wk, ld in enumerate(self.loaders):
                    order = np.zeros((padded, bsz), np.int32)
                    order[:steps] = (
                        ld.epoch_order(epoch)[: steps * bsz]
                        .astype(np.int32)
                        .reshape(steps, bsz)
                    )
                    idx[:, wk] = order.reshape(rounds, h, bsz)
                valid = np.zeros((rounds * h,), np.float32)
                valid[:steps] = 1.0
                lrs = np.zeros((rounds * h,), np.float32)
                lrs[:steps] = [self._lr(gstep + i, epoch) for i in range(steps)]
                self.key, sub = jax.random.split(self.key)
                keys = jax.random.split(sub, rounds * k).reshape(rounds, k, 2)
                epoch_args = (
                    params, opt_state, topo, x_all, y_all,
                    jnp.asarray(idx), jnp.asarray(lrs.reshape(rounds, h)),
                    jnp.asarray(valid.reshape(rounds, h)), keys,
                )

                def run_epoch():
                    # hook first: a kill/transient fires before the pure
                    # device call, so retry_step re-enters with identical
                    # inputs
                    if self.fault_hook is not None:
                        self.fault_hook(gstep)
                    if weights is None:
                        return self._epoch_fn(*epoch_args)
                    return self._weighted_epoch_fn()(
                        *epoch_args, jnp.asarray(weights)
                    )

                # jitted-call boundary: the whole epoch's sync rounds are one
                # device call; registered outputs are blocked on at span
                # close (the code below blocks on the same values anyway)
                with obs.span(
                    "wasap.sync_rounds", rounds=rounds, h=h,
                    elastic=weights is not None,
                ) as sr_sp:
                    if self.step_retries:
                        out = retry_step(
                            run_epoch,
                            retries=self.step_retries,
                            backoff_s=self.retry_backoff_s,
                        )
                    else:
                        out = run_epoch()
                    # the elastic (weighted) program stays probe-off: its
                    # epochs simply record no snapshot
                    if wc.probe and weights is None:
                        params, opt_state, loss_sums, probe_dev = out
                    else:
                        params, opt_state, loss_sums = out
                        probe_dev = None
                    sr_sp.block_on(loss_sums)
                gstep += steps
                # master topology evolution on the averaged model; momentum
                # is re-aligned (RetainValidUpdates semantics for velocity)
                self.key, sub = jax.random.split(self.key)
                topo, params, opt_state = self._evolve_master_device(
                    topo, params, opt_state, sub
                )
                obs.point("wasap.evolve", epoch=epoch, device=True)
                # dispatch is async — wait for the epoch's device work so
                # epoch_seconds measures compute, not enqueue
                jax.block_until_ready((params, loss_sums))
                dt = time.perf_counter() - t0
                train_loss = float(jnp.sum(loss_sums)) / (k * steps)
                acc = evaluate(
                    model, self.data.x_test, self.data.y_test,
                    params=params, topo_arrays=topo,
                )
                # host-side recording after the block (§11 obs-in-jit)
                if probe_dev is not None:
                    churn = None
                    if self._last_churn is not None:
                        counts, nnz = self._last_churn
                        churn = [
                            float(c) / max(1, n)
                            for c, n in zip(np.asarray(counts), nnz)
                        ]
                        self._last_churn = None
                    probes.record_snapshot(
                        gstep, "wasap", probe_dev, churn=churn,
                        extra={
                            "epoch": epoch, "phase": 1,
                            "loss": train_loss, "acc": float(acc),
                        },
                    )
                ep_sp.set(loss=train_loss, acc=float(acc))
                self._log(epoch, 1, train_loss, dt, acc)
                self._p1_state = (params, opt_state, topo)
                self.epoch_next = epoch + 1
                if self.epoch_end_hook is not None:
                    self.epoch_end_hook(self, epoch)
        model.set_params(params)
        self._sync_topos_to_host(topo)
        self.epoch_next = wc.phase1_epochs

    def _run_phase1_roundloop(self) -> None:
        """Seed-era phase 1: per-round Python dispatch, host replication,
        numpy batch stacking, host numpy evolution — the fused baseline."""
        wc, model = self.wc, self.model
        k, h = wc.n_workers, self._h
        gstep = 0
        params = model.params()
        opt_state = self.opt.init(params)
        for epoch in range(wc.phase1_epochs):
            t0 = time.perf_counter()
            topo = model.topo_arrays()
            batches = [list(ld.epoch(epoch)) for ld in self.loaders]
            steps = min(len(b) for b in batches)
            if steps == 0:
                raise ValueError("batch_size larger than the worker shards")
            loss_total, s = 0.0, 0
            while s < steps:
                hh = min(h, steps - s)
                # pad the tail round to a static H (valid-masked) so one
                # compile serves the whole run
                xs = np.zeros(
                    (k, h) + batches[0][0][0].shape, batches[0][0][0].dtype
                )
                ys = np.zeros((k, h) + batches[0][0][1].shape, batches[0][0][1].dtype)
                for wk, b in enumerate(batches):
                    for i in range(hh):
                        xs[wk, i], ys[wk, i] = b[s + i]
                valid = np.zeros((h,), np.float32)
                valid[:hh] = 1.0
                lrs = np.zeros((h,), np.float32)
                lrs[:hh] = [self._lr(gstep + i, epoch) for i in range(hh)]
                self.key, *subs = jax.random.split(self.key, k + 1)
                sp = _replicate(params, k)
                so = _replicate(opt_state, k)
                sp, so, lsum = self._round(
                    sp, so, topo, jnp.asarray(xs), jnp.asarray(ys),
                    jnp.asarray(lrs), jnp.asarray(valid), jnp.stack(subs),
                )
                params = _cast_like(_average_workers(sp), params)
                if wc.average_momentum:
                    opt_state = _cast_like(_average_workers(so), opt_state)
                else:
                    opt_state = _take_worker0(so)
                loss_total += float(lsum.sum())
                s += hh
                gstep += hh
            model.set_params(params)
            # master topology evolution on the averaged model (host numpy)
            self._evolve_master(opt_state)
            params = model.params()
            opt_state = self._realigned_opt_state
            dt = time.perf_counter() - t0
            acc = evaluate(model, self.data.x_test, self.data.y_test)
            self._log(epoch, 1, loss_total / (k * steps), dt, acc)

    # -- phase 2: independent local training ---------------------------------

    def _run_phase2_fused(self) -> List[tuple]:
        """Each worker owns a device-resident replica: fused epoch segments
        (one jitted call per worker-epoch) + device topology evolution."""
        wc, model = self.wc, self.model
        cfg = model.config
        k, bsz = wc.n_workers, wc.batch_size
        x_all, y_all = self._data_on_device()
        if self._p2_workers is not None:  # resumed at an epoch boundary
            workers = self._p2_workers
        else:
            base = model.params()
            workers = []
            for wk in range(k):
                self.key, sub = jax.random.split(self.key)
                workers.append({
                    # per-worker copies: segments donate their buffers off-CPU
                    "params": jax.tree.map(jnp.array, base),
                    "opt": self.opt.init(base),
                    "topo": model.topo_arrays(),
                    "key": sub,
                })
        steps_per_epoch = min(ld.steps_per_epoch for ld in self.loaders)
        start = max(self.start_epoch, wc.phase1_epochs)
        for epoch in range(start, wc.phase1_epochs + wc.phase2_epochs):
            with obs.span(
                "wasap.epoch", epoch=epoch, phase=2, workers=k
            ) as ep_sp:
                t0 = time.perf_counter()
                if self.fault_hook is not None:
                    self.fault_hook(epoch * steps_per_epoch)
                losses = []
                p2_probe = None       # worker 0's device probe stats
                p2_churn = None       # worker 0's (n_pruned, nnz)
                # one span over all K worker segments+evolutions: the calls
                # are enqueued asynchronously across workers and blocked on
                # once, so a per-worker span would serialize the device queue
                with obs.span("wasap.worker_segments", workers=k) as ws_sp:
                    for wk in range(k):
                        w = workers[wk]
                        ld = self.loaders[wk]
                        steps = ld.steps_per_epoch
                        perm = jnp.asarray(
                            ld.epoch_order(epoch).astype(np.int32).reshape(
                                steps, bsz
                            )
                        )
                        lrs = jnp.full((steps,), wc.lr, jnp.float32)
                        # worker 0 carries the probes: one representative
                        # replica is enough for phase-2 dynamics and keeps
                        # the other K-1 programs byte-identical to probe-off
                        probing = self._probe_segment is not None and wk == 0
                        seg = self._probe_segment if probing else self._segment
                        out = seg(
                            w["params"], w["opt"], w["topo"], x_all, y_all,
                            perm, lrs, w["key"],
                        )
                        if probing:
                            w["params"], w["opt"], w["key"], ls, p2_probe = out
                        else:
                            w["params"], w["opt"], w["key"], ls = out
                        losses.append(ls)
                        # per-worker evolution (divergent topologies)
                        w["key"], sub = jax.random.split(w["key"])
                        if probing:
                            w["topo"], vals, vel, pruned = (
                                evolve_element_layers_device(
                                    w["topo"], list(w["params"]["values"]),
                                    list(w["opt"].velocity["values"]), sub,
                                    layer_dims=cfg.layer_dims, zeta=wc.zeta,
                                    init_scheme=cfg.init, probe=True,
                                )
                            )
                            p2_churn = (
                                pruned,
                                [int(t.rows.shape[0]) for t in w["topo"]],
                            )
                        else:
                            w["topo"], vals, vel = evolve_element_layers_device(
                                w["topo"], list(w["params"]["values"]),
                                list(w["opt"].velocity["values"]), sub,
                                layer_dims=cfg.layer_dims, zeta=wc.zeta,
                                init_scheme=cfg.init,
                            )
                        w["params"] = {
                            "values": tuple(vals),
                            "biases": w["params"]["biases"],
                        }
                        w["opt"] = replace_values_velocity(w["opt"], vel)
                    ws_sp.block_on([w["params"] for w in workers])
                jax.block_until_ready([w["params"] for w in workers])
                dt = time.perf_counter() - t0
                loss = float(np.mean([np.asarray(l).mean() for l in losses]))
                # host-side recording after the block (§11 obs-in-jit)
                if p2_probe is not None:
                    churn = None
                    if p2_churn is not None:
                        counts, nnz = p2_churn
                        churn = [
                            float(c) / max(1, n)
                            for c, n in zip(np.asarray(counts), nnz)
                        ]
                    probes.record_snapshot(
                        (epoch + 1) * steps_per_epoch, "wasap", p2_probe,
                        churn=churn,
                        extra={"epoch": epoch, "phase": 2, "loss": loss},
                    )
                ep_sp.set(loss=loss)
                self._log(epoch, 2, loss, dt, float("nan"))
                self._p2_workers = workers
                self.epoch_next = epoch + 1
                if self.epoch_end_hook is not None:
                    self.epoch_end_hook(self, epoch)
        out = []
        for w in workers:
            topos = [
                ElementTopology(
                    cfg.layer_dims[l], cfg.layer_dims[l + 1],
                    np.asarray(t.rows), np.asarray(t.cols),
                )
                for l, t in enumerate(w["topo"])
            ]
            vals = [np.asarray(v, np.float32) for v in w["params"]["values"]]
            out.append((topos, vals, list(w["params"]["biases"])))
        return out

    def _run_phase2_perbatch(self) -> List[tuple]:
        """Seed-era phase 2: per-batch dispatch + host numpy evolution."""
        wc, model = self.wc, self.model
        cfg = model.config
        k = wc.n_workers
        worker_models = []
        for wk in range(k):
            m = SparseMLP(cfg, seed=wc.seed)  # structure placeholder
            m.topos = [t for t in model.topos]
            m.values = [v for v in model.values]
            m.biases = [b for b in model.biases]
            worker_models.append(m)
        worker_opt = [self.opt.init(m.params()) for m in worker_models]
        worker_rngs = [np.random.default_rng(wc.seed * 97 + 13 * wk) for wk in range(k)]
        step_fn = make_step_fn(cfg, self.opt)
        for epoch in range(wc.phase1_epochs, wc.phase1_epochs + wc.phase2_epochs):
            t0 = time.perf_counter()
            losses = []
            for wk in range(k):
                m = worker_models[wk]
                params = m.params()
                topo = m.topo_arrays()
                ostate = worker_opt[wk]
                for xb, yb in self.loaders[wk].epoch(epoch):
                    self.key, sub = jax.random.split(self.key)
                    params, ostate, loss = step_fn(
                        params, ostate, topo,
                        jnp.asarray(xb), jnp.asarray(yb),
                        jnp.asarray(wc.lr, jnp.float32), sub,
                    )
                    losses.append(float(loss))
                m.set_params(params)
                # per-worker evolution (divergent topologies)
                vel = list(ostate.velocity["values"])
                for l in range(cfg.n_layers):
                    res = evolve_element(
                        m.topos[l],
                        np.asarray(m.values[l], np.float32),
                        wc.zeta,
                        worker_rngs[wk],
                        momentum=np.asarray(vel[l], np.float32),
                        init_scheme=cfg.init,
                    )
                    m.topos[l] = res.topology
                    m.values[l] = jnp.asarray(res.values)
                    vel[l] = jnp.asarray(res.momentum)
                worker_opt[wk] = replace_values_velocity(ostate, vel)
            dt = time.perf_counter() - t0
            self._log(epoch, 2, float(np.mean(losses)) if losses else float("nan"),
                      dt, float("nan"))
        return [
            (
                list(m.topos),
                [np.asarray(v, np.float32) for v in m.values],
                list(m.biases),
            )
            for m in worker_models
        ]

    # -- final: SWA + re-sparsify --------------------------------------------

    def _merge_workers(self, worker_states: List[tuple]) -> None:
        model = self.model
        cfg = model.config
        target_nnz = [t.nnz for t in model.topos]
        for l in range(cfg.n_layers):
            topo, vals = sparse_average_and_resparsify(
                [ws[0][l] for ws in worker_states],
                [ws[1][l] for ws in worker_states],
                target_nnz[l],
            )
            model.topos[l] = topo
            model.values[l] = jnp.asarray(vals)
            model.biases[l] = jnp.mean(
                jnp.stack([ws[2][l] for ws in worker_states]), axis=0
            )

    # -- elasticity (DESIGN.md §8) -------------------------------------------

    def _weighted_epoch_fn(self):
        """Weighted-average variant of the phase-1 epoch, built (and jitted)
        only when a heartbeat monitor is attached — the unweighted program
        keeps its exact float reduction order otherwise."""
        if self._epoch_fn_weighted is None:
            self._epoch_fn_weighted = make_phase1_epoch_fn(
                self.model.config, self.opt,
                n_workers=self.wc.n_workers,
                average_momentum=self.wc.average_momentum,
                worker_axis=self.wc.worker_axis,
                mesh=self._mesh,
                weighted=True,
            )
        return self._epoch_fn_weighted

    def _worker_weights(self, epoch: int) -> np.ndarray:
        """One heartbeat interval per epoch: deliver the beats that arrived
        (``beat_filter`` suppresses an injected straggler's), tick the
        monitor, and weight the round's average 1/0 by liveness. The weights
        are renormalized inside ``_average_pytree``, so the round completes
        elastically over the survivors — the evicted worker's shard still
        trains (its replica exists on device) but contributes nothing."""
        k = self.wc.n_workers
        mon = self.monitor
        for wk in range(k):
            wid = f"w{wk}"
            if wid in mon.evicted:
                continue
            if self.beat_filter is None or self.beat_filter(wid, epoch):
                mon.beat(wid)
        status = mon.tick()
        weights = np.asarray(
            [
                1.0
                if status.get(f"w{wk}", "healthy") in ("healthy", "straggling")
                else 0.0
                for wk in range(k)
            ],
            np.float32,
        )
        if weights.sum() == 0:
            raise RuntimeError(
                "every WASAP worker is dead/evicted — the round cannot "
                "complete elastically"
            )
        self.elastic_log.append(
            {
                "epoch": epoch,
                "status": {f"w{wk}": status.get(f"w{wk}") for wk in range(k)},
                "weights": weights.tolist(),
            }
        )
        return weights

    # -- resume (DESIGN.md §8) ------------------------------------------------

    def save_checkpoint(self, manager) -> None:
        """Phase-aware epoch-boundary snapshot for the fused path. Phase 1
        saves the averaged master (params + velocity + topology); phase 2
        additionally saves every worker replica (params/velocity/topology/
        PRNG key) as extra groups, since the replicas have diverged. Both
        carry the trainer's PRNG streams and history, so a restore replays
        the remaining epochs bit-exactly."""
        if not self._fused:
            raise RuntimeError(
                "WASAP checkpointing covers the fused path; the seed-era "
                "round loop is a measured baseline, not a production path"
            )
        cfg = self.model.config
        resume = {
            "kind": "wasap",
            "phase": self._phase,
            "epoch_next": int(self.epoch_next),
            "jax_key": np.asarray(self.key).tolist(),
            "numpy_rng": self.rng.bit_generator.state,
            "history": self.history,
        }

        def topo_entry(topo_l):
            return {
                "rows": np.asarray(topo_l.rows),
                "cols": np.asarray(topo_l.cols),
            }

        if self._phase == 1 and self._p1_state is not None:
            params, opt_state, topo = self._p1_state
            resume["opt_step"] = int(opt_state.step)
            manager.save(
                self.epoch_next,
                params,
                extra={"velocity": opt_state.velocity},
                topologies={
                    f"layer{l}": topo_entry(topo[l])
                    for l in range(cfg.n_layers)
                },
                meta={"resume": resume},
            )
            return
        # phase 2 (or the phase boundary itself): master + worker replicas
        topologies = {
            f"layer{l}": topo_entry(self.model.topos[l])
            for l in range(cfg.n_layers)
        }
        extra = {}
        worker_keys, worker_opt_steps = [], []
        for wk, w in enumerate(self._p2_workers or []):
            extra[f"w{wk}_params"] = w["params"]
            extra[f"w{wk}_velocity"] = w["opt"].velocity
            worker_keys.append(np.asarray(w["key"]).tolist())
            worker_opt_steps.append(int(w["opt"].step))
            for l in range(cfg.n_layers):
                topologies[f"w{wk}_layer{l}"] = topo_entry(w["topo"][l])
        resume["phase"] = 2
        resume["n_saved_workers"] = len(worker_keys)
        resume["worker_keys"] = worker_keys
        resume["worker_opt_steps"] = worker_opt_steps
        manager.save(
            self.epoch_next,
            self.model.params(),
            extra=extra,
            topologies=topologies,
            meta={"resume": resume},
        )

    def restore_checkpoint(self, manager, step=None) -> int:
        """Rewind to a saved epoch boundary (newest *valid* checkpoint by
        default — corrupt ones are quarantined by the scan); ``run()`` then
        continues from the saved phase and epoch."""
        from repro.train.trainer import _params_like

        if step is None:
            step = manager.latest_valid_step()
            if step is None:
                raise FileNotFoundError(f"no valid checkpoints under {manager.dir}")
        manifest = manager.read_manifest(step)
        res = manifest["meta"]["resume"]
        cfg = self.model.config
        k = self.wc.n_workers
        like = _params_like(manifest["shapes"], cfg.n_layers)

        def layer_topo(l, entry) -> ElementTopology:
            return ElementTopology(
                cfg.layer_dims[l], cfg.layer_dims[l + 1],
                entry["rows"], entry["cols"],
            )

        if res["phase"] == 1:
            params, extra, topologies, _ = manager.restore(
                step, like=like, like_extra={"velocity": like}
            )
            topo = tuple(
                layer_topo(l, topologies[f"layer{l}"]).device_arrays()
                for l in range(cfg.n_layers)
            )
            self._p1_state = (
                jax.tree.map(jnp.asarray, params),
                SGDState(
                    velocity=jax.tree.map(jnp.asarray, extra["velocity"]),
                    step=jnp.asarray(res["opt_step"], jnp.int32),
                ),
                topo,
            )
            self._phase = 1
        else:
            n_saved = int(res.get("n_saved_workers", k))
            like_extra = {}
            for wk in range(n_saved):
                like_extra[f"w{wk}_params"] = like
                like_extra[f"w{wk}_velocity"] = like
            params, extra, topologies, _ = manager.restore(
                step, like=like, like_extra=like_extra
            )
            for l in range(cfg.n_layers):
                self.model.topos[l] = layer_topo(l, topologies[f"layer{l}"])
            self.model.set_params(jax.tree.map(jnp.asarray, params))
            workers = []
            for wk in range(n_saved):
                workers.append({
                    "params": jax.tree.map(jnp.asarray, extra[f"w{wk}_params"]),
                    "opt": SGDState(
                        velocity=jax.tree.map(
                            jnp.asarray, extra[f"w{wk}_velocity"]
                        ),
                        step=jnp.asarray(res["worker_opt_steps"][wk], jnp.int32),
                    ),
                    "topo": tuple(
                        layer_topo(l, topologies[f"w{wk}_layer{l}"])
                        .device_arrays()
                        for l in range(cfg.n_layers)
                    ),
                    "key": jnp.asarray(res["worker_keys"][wk], jnp.uint32),
                })
            self._p2_workers = workers if workers else None
            self._phase = 2
        self.key = jnp.asarray(res["jax_key"], jnp.uint32)
        self.rng.bit_generator.state = res["numpy_rng"]
        self.start_epoch = self.epoch_next = int(res["epoch_next"])
        self.history = {k2: list(v) for k2, v in res["history"].items()}
        return step

    # -- helpers --------------------------------------------------------------

    def _evolve_master_device(self, topo, params, opt_state, key):
        cfg, wc = self.model.config, self.wc
        if wc.probe:
            topo, values, vel, pruned = evolve_element_layers_device(
                topo, list(params["values"]),
                list(opt_state.velocity["values"]), key,
                layer_dims=cfg.layer_dims, zeta=wc.zeta,
                init_scheme=cfg.init, probe=True,
            )
            self._last_churn = (
                pruned, [int(t.rows.shape[0]) for t in topo]
            )
        else:
            topo, values, vel = evolve_element_layers_device(
                topo, list(params["values"]),
                list(opt_state.velocity["values"]), key,
                layer_dims=cfg.layer_dims, zeta=wc.zeta, init_scheme=cfg.init,
            )
        params = {"values": tuple(values), "biases": params["biases"]}
        return topo, params, replace_values_velocity(opt_state, vel)

    def _sync_topos_to_host(self, topo) -> None:
        cfg = self.model.config
        for l in range(cfg.n_layers):
            self.model.topos[l] = ElementTopology(
                cfg.layer_dims[l], cfg.layer_dims[l + 1],
                np.asarray(topo[l].rows), np.asarray(topo[l].cols),
            )

    def _evolve_master(self, opt_state: SGDState) -> None:
        model, wc = self.model, self.wc
        cfg = model.config
        vel = list(opt_state.velocity["values"])
        for l in range(cfg.n_layers):
            res = evolve_element(
                model.topos[l],
                np.asarray(model.values[l], np.float32),
                wc.zeta,
                self.rng,
                momentum=np.asarray(vel[l], np.float32),
                init_scheme=cfg.init,
            )
            model.topos[l] = res.topology
            model.values[l] = jnp.asarray(res.values)
            vel[l] = jnp.asarray(res.momentum)
        self._realigned_opt_state = replace_values_velocity(opt_state, vel)

    def _log(self, epoch, phase, loss, dt, acc) -> None:
        self.history["epoch"].append(epoch)
        self.history["phase"].append(phase)
        self.history["train_loss"].append(loss)
        self.history["test_acc"].append(acc)
        self.history["n_params"].append(self.model.n_params)
        self.history["epoch_seconds"].append(dt)


# ---------------------------------------------------------------------------
# contract auditor registration (repro.analysis, DESIGN.md §10)
# ---------------------------------------------------------------------------


def analysis_programs():
    """Registry hook: the phase-1 fused epoch (K vmapped workers, scan over
    sync rounds). The audit model pins ``element_impl="custom"`` so the
    structural checks exercise the custom-VJP kernels even at the tiny
    audit scale (below the auto-dispatch nnz threshold)."""
    from repro.analysis.registry import AuditProgram, Contract, ProgramSpec

    dims = (20, 16, 10)
    K, R, H, B = 2, 2, 2, 8

    def build() -> AuditProgram:
        cfg = SparseMLPConfig(
            layer_dims=dims, epsilon=6, dropout=0.0, element_impl="custom"
        )
        model = SparseMLP(cfg, seed=0)
        opt = MomentumSGD(momentum=0.9, weight_decay=2e-4)
        n_train = R * H * B
        args = (
            model.params(),
            opt.init(model.params()),
            model.topo_arrays(),
            jnp.zeros((n_train, dims[0]), jnp.float32),
            jnp.zeros((n_train,), jnp.int32),
            jnp.arange(R * K * H * B, dtype=jnp.int32).reshape(R, K, H, B)
            % n_train,
            jnp.full((R, H), 0.01, jnp.float32),
            jnp.ones((R, H), jnp.float32),
            jnp.zeros((R, K, 2), jnp.uint32),
        )
        nnz = [int(t.rows.shape[0]) for t in model.topos]
        return AuditProgram(
            make=lambda donate: make_phase1_epoch_fn(
                cfg, opt, n_workers=K, donate=donate
            ),
            args=args,
            meta={"dims": dims, "workers": K, "rounds": R, "nnz": nnz},
        )

    return [
        ProgramSpec(
            name="wasap.phase1_epoch",
            subsystem=__name__,
            contract=Contract(
                # one CE-loss label scatter, batched over the K worker vmap
                max_unsorted_scatter=1,
                max_unsorted_scatter_elems=K * B * dims[-1],
                max_intermediate_elems=256 * 1024,
                donate_argnums=(0, 1),
                max_temp_bytes=4 * 1024 * 1024,
                expected_compiles=1,
            ),
            build=build,
            notes="K-worker vmapped local SGD + on-device average per round",
        )
    ]
