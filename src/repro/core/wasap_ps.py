"""Paper-faithful WASAP-SGD phase 1: asynchronous parameter server.

This is the literal Algorithm 1 protocol (Dean-style PS over shared memory),
kept for the CPU MLP experiments and as the reference semantics for the SPMD
adaptation in wasap.py:

  * K worker threads repeatedly: fetch (model, t'), compute a gradient on
    their own mini-batch, push (grad, t) — no barrier between workers.
  * The PS thread applies each incoming gradient with momentum SGD, after
    `RetainValidUpdates` filters entries whose connections no longer exist
    (the topology may have evolved since the worker fetched).
  * Every n/B applied updates (one "epoch"), the PS pauses to run the SET
    topology-evolution step; the worker may thus be arbitrarily stale.

Straggler mitigation is inherent: a slow worker delays only itself — its
update is still merged when it arrives (optionally down-weighted by
staleness). `straggler_delay` injects synthetic stragglers for tests.

jit-compiled gradient computation releases the GIL so threads overlap
meaningfully even on CPU.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sparsity import ElementTopology
from repro.core.topology import evolve_element, retain_valid_updates_element
from repro.data.loader import ShardedLoader
from repro.data.synthetic import Dataset
from repro.models.mlp import SparseMLP, cross_entropy_loss, mlp_forward

__all__ = ["AsyncPSConfig", "AsyncParameterServer"]


@dataclasses.dataclass
class AsyncPSConfig:
    n_workers: int = 4
    epochs: int = 4                # tau_1
    lr: float = 0.01
    momentum: float = 0.9
    weight_decay: float = 2e-4
    zeta: float = 0.3
    batch_size: int = 32
    seed: int = 0
    # Staleness-adaptive LR (MindTheStep-style): scales each update by
    # 1/(1 + discount * staleness). Asynchrony adds *implicit* momentum
    # (Mitliagkas et al. 2016, cited by the paper) on top of the explicit
    # mu=0.9; at this emulation's tiny-step scale that diverges without a
    # discount, so a mild default is on. Set 0.0 for the paper's plain async.
    staleness_discount: float = 0.25
    straggler_delay: float = 0.0      # seconds injected into worker 0 (tests)
    evolve: bool = True


class AsyncParameterServer:
    """Shared-state PS with atomic (locked) fetch/push, per Figure 2."""

    def __init__(self, model: SparseMLP, data: Dataset, cfg: AsyncPSConfig):
        assert model.config.impl == "element"
        self.model = model
        self.data = data
        self.cfg = cfg
        self.lock = threading.Lock()
        self.grad_queue: "queue.Queue" = queue.Queue(maxsize=cfg.n_workers * 2)
        self.t_global = 0          # PS update counter  (t' in Algorithm 1)
        self.topo_version = 0
        self.stop_flag = threading.Event()
        self.rng = np.random.default_rng(cfg.seed)
        mcfg = model.config
        # velocity per layer (element values) + biases
        self.vel_values = [np.zeros(t.nnz, np.float32) for t in model.topos]
        self.vel_biases = [np.zeros(int(b.size), np.float32) for b in model.biases]
        self.applied_updates = 0
        self.stats = {
            "stale_entries_dropped": 0,
            "updates": 0,
            "evolutions": 0,
            "queue_full_retries": 0,
            "grads_dropped": 0,
        }
        # per-epoch snapshots of the counters above (cumulative), surfaced in
        # run()'s return under "history" so drops/retries are attributable to
        # an epoch instead of only a final total
        self.history: Dict[str, List[int]] = {
            "epoch": [], **{k: [] for k in self.stats}
        }

        self._grad_fn = self._make_grad_fn()
        self.steps_per_epoch = (
            data.x_train.shape[0] // cfg.batch_size
        )

    def _make_grad_fn(self):
        config = self.model.config

        @jax.jit
        def grad_fn(params, topo, x, y, rng):
            def loss_fn(p):
                logits = mlp_forward(p, topo, x, config, train=True, rng=rng)
                return cross_entropy_loss(logits, y)

            loss, grads = jax.value_and_grad(loss_fn)(params)
            return loss, grads

        return grad_fn

    # -- atomic PS ops (Figure 2: atomic read / write) ----------------------

    def fetch(self):
        with self.lock:
            snapshot = (
                [t for t in self.model.topos],        # immutable objects
                [np.asarray(v) for v in self.model.values],
                [np.asarray(b) for b in self.model.biases],
                self.topo_version,
                self.t_global,
            )
        return snapshot

    def push(self, grads_values, grads_biases, topo_version, t_worker):
        self.grad_queue.put((grads_values, grads_biases, topo_version, t_worker))

    # -- server loop ---------------------------------------------------------

    def _apply(self, gv: List[np.ndarray], gb, worker_topos, staleness: int):
        cfg = self.cfg
        scale = 1.0 / (1.0 + cfg.staleness_discount * staleness)
        with self.lock:
            for l in range(len(self.model.values)):
                g = gv[l]
                if worker_topos is not None:
                    # Algorithm 1 line 14: retain only valid updates
                    before = np.count_nonzero(g)
                    g = retain_valid_updates_element(
                        g, worker_topos[l], self.model.topos[l]
                    )
                    self.stats["stale_entries_dropped"] += int(
                        before - np.count_nonzero(g)
                    )
                v = np.asarray(self.model.values[l], np.float32)
                g = g + cfg.weight_decay * v
                self.vel_values[l] = (
                    cfg.momentum * self.vel_values[l] - cfg.lr * scale * g
                )
                self.model.values[l] = jnp.asarray(v + self.vel_values[l])
                b = np.asarray(self.model.biases[l], np.float32)
                gbl = gb[l] + cfg.weight_decay * b
                self.vel_biases[l] = (
                    cfg.momentum * self.vel_biases[l] - cfg.lr * scale * gbl
                )
                self.model.biases[l] = jnp.asarray(b + self.vel_biases[l])
            self.t_global += 1
            self.stats["updates"] += 1

    def _evolve(self):
        cfg = self.cfg
        with self.lock:  # master pauses async updates (Algorithm 1 line 16-18)
            for l in range(len(self.model.topos)):
                res = evolve_element(
                    self.model.topos[l],
                    np.asarray(self.model.values[l], np.float32),
                    cfg.zeta,
                    self.rng,
                    momentum=self.vel_values[l],
                    init_scheme=self.model.config.init,
                )
                self.model.topos[l] = res.topology
                self.model.values[l] = jnp.asarray(res.values)
                self.vel_values[l] = res.momentum
            self.topo_version += 1
            self.stats["evolutions"] += 1

    def _server_loop(self):
        cfg = self.cfg
        total_updates = cfg.epochs * self.steps_per_epoch
        while self.applied_updates < total_updates:
            try:
                gv, gb, tv, tw = self.grad_queue.get(timeout=5.0)
            except queue.Empty:
                if self.stop_flag.is_set():
                    return
                continue
            worker_topos = gv.pop("topos")
            staleness = self.t_global - tw
            self._apply(
                gv["values"], gb,
                worker_topos if tv != self.topo_version else None,
                staleness,
            )
            self.applied_updates += 1
            if (
                self.applied_updates % self.steps_per_epoch == 0
                and self.applied_updates < total_updates
            ):
                if cfg.evolve:
                    self._evolve()
                self._snapshot_stats(self.applied_updates // self.steps_per_epoch)
        self.stop_flag.set()

    def _snapshot_stats(self, epoch: int) -> None:
        with self.lock:
            self.history["epoch"].append(epoch)
            for k, v in self.stats.items():
                self.history[k].append(int(v))

    # -- worker loop -----------------------------------------------------------

    def _worker_loop(self, wid: int):
        cfg = self.cfg
        loader = ShardedLoader(
            self.data.x_train, self.data.y_train, cfg.batch_size,
            seed=cfg.seed, shard_id=wid, num_shards=cfg.n_workers,
        )
        key = jax.random.PRNGKey(cfg.seed * 131 + wid)
        epoch = 0
        while not self.stop_flag.is_set():
            for xb, yb in loader.epoch(epoch):
                if self.stop_flag.is_set():
                    return
                topos, values, biases, tv, tw = self.fetch()
                topo_arrays = tuple(t.device_arrays() for t in topos)
                params = {
                    "values": tuple(jnp.asarray(v) for v in values),
                    "biases": tuple(jnp.asarray(b) for b in biases),
                }
                key, sub = jax.random.split(key)
                _, grads = self._grad_fn(
                    params, topo_arrays, jnp.asarray(xb), jnp.asarray(yb), sub
                )
                if cfg.straggler_delay and wid == 0:
                    time.sleep(cfg.straggler_delay)
                gv = {
                    "values": [np.asarray(g, np.float32) for g in grads["values"]],
                    "topos": topos,
                }
                gb = [np.asarray(g, np.float32) for g in grads["biases"]]
                # a full queue means the PS is momentarily behind — keep
                # retrying the push for THIS gradient rather than silently
                # discarding the computed work and advancing to the next batch
                pushed = False
                while not self.stop_flag.is_set():
                    try:
                        self.grad_queue.put((gv, gb, tv, tw), timeout=1.0)
                        pushed = True
                        break
                    except queue.Full:
                        with self.lock:
                            self.stats["queue_full_retries"] += 1
                if not pushed:
                    # shutdown raced the retry. A gradient the completed run
                    # never needed is surplus pipelined work, not a loss —
                    # only a gradient the run still required counts as
                    # dropped, so a clean shutdown reports zero drops.
                    total = self.cfg.epochs * self.steps_per_epoch
                    with self.lock:
                        if self.applied_updates < total:
                            self.stats["grads_dropped"] += 1
                    return
            epoch += 1

    # -- entry -----------------------------------------------------------------

    def run(self) -> Dict[str, object]:
        server = threading.Thread(target=self._server_loop, daemon=True)
        workers = [
            threading.Thread(target=self._worker_loop, args=(w,), daemon=True)
            for w in range(self.cfg.n_workers)
        ]
        t0 = time.perf_counter()
        server.start()
        for w in workers:
            w.start()
        server.join()
        self.stop_flag.set()
        for w in workers:
            w.join(timeout=10.0)
        # final snapshot AFTER workers exit, so drops charged during the
        # shutdown race are attributed to the last epoch rather than lost
        self._snapshot_stats(self.cfg.epochs)
        return {
            "seconds": time.perf_counter() - t0,
            **self.stats,
            "topo_version": self.topo_version,
            "history": self.history,
        }
