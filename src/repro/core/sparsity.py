"""Truly sparse weight representations.

Two granularities, both storing ONLY the live parameters (no dense mask):

* ``ElementSparse`` — COO element-level sparsity. This is the paper-faithful
  representation (SciPy-CSR equivalent) used for the SET-MLP experiments.
  Compute is a gather/scatter-add SpMM whose FLOP count is O(B * nnz).

* ``BlockSparse`` — MXU-aligned block sparsity (TPU adaptation, see DESIGN.md
  §2). Active (block_m, block_n) tiles are stored as a compact
  ``(n_blocks, bm, bn)`` array plus int32 block coordinates. Compute goes
  through either a Pallas kernel (``repro.kernels``) or an XLA-native
  gather/segment-sum einsum whose FLOPs also scale with the live block count.

Topology (coordinates) is intentionally kept in host numpy and treated as
non-trainable data: SET evolution / Importance Pruning happen *between* jitted
train segments (the paper evolves once per epoch on the master), so the jitted
step functions only ever see fixed-capacity arrays and never recompile when
connections move.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "BlockMeta",
    "BlockTopoArrays",
    "BlockTopology",
    "ElementTopology",
    "density_from_epsilon",
    "element_spmm",
    "element_spmm_segment",
    "erdos_renyi_nnz",
]


def density_from_epsilon(epsilon: float, n_in: int, n_out: int) -> float:
    """SET's Erdős–Rényi density: p = eps * (n_in + n_out) / (n_in * n_out)."""
    return min(1.0, float(epsilon) * (n_in + n_out) / (n_in * n_out))


def erdos_renyi_nnz(epsilon: float, n_in: int, n_out: int) -> int:
    return max(1, int(round(density_from_epsilon(epsilon, n_in, n_out) * n_in * n_out)))


# ---------------------------------------------------------------------------
# Block sparsity
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BlockMeta:
    """Static metadata of a block-sparse matrix (hashable; safe for jit)."""

    in_dim: int
    out_dim: int
    block_m: int = 128
    block_n: int = 128

    @property
    def grid_m(self) -> int:
        return -(-self.in_dim // self.block_m)

    @property
    def grid_n(self) -> int:
        return -(-self.out_dim // self.block_n)

    @property
    def padded_in(self) -> int:
        return self.grid_m * self.block_m

    @property
    def padded_out(self) -> int:
        return self.grid_n * self.block_n

    @property
    def total_blocks(self) -> int:
        return self.grid_m * self.grid_n


class BlockTopoArrays(NamedTuple):
    """Device-side topology for the kernels. All int32, shape (n_blocks,).

    Canonical order is sorted by (col, row) — required by the forward kernel's
    output-revisit accumulation. ``*_r`` fields are the same topology sorted by
    (row, col) for the dX kernel; ``perm_r[i]`` maps row-ordered slot i back to
    the canonical slot owning its values.
    """

    rows: jax.Array
    cols: jax.Array
    first_col: jax.Array  # 1 where cols[i] != cols[i-1]
    rows_r: jax.Array
    cols_r: jax.Array
    first_row: jax.Array  # 1 where rows_r[i] != rows_r[i-1]
    perm_r: jax.Array


def _first_flags(keys: np.ndarray) -> np.ndarray:
    first = np.ones_like(keys, dtype=np.int32)
    if keys.size > 1:
        first[1:] = (keys[1:] != keys[:-1]).astype(np.int32)
    return first


class BlockTopology:
    """Host-side (numpy) block topology with SET bookkeeping.

    Invariants:
      * slots sorted by (col, row); positions unique
      * every block-column in [0, grid_n) is covered by >= 1 slot
        ("no output neuron without incoming connections"); coverage slots may
        be zero-valued but keep the Pallas output-tile zeroing correct.
    """

    def __init__(self, meta: BlockMeta, rows: np.ndarray, cols: np.ndarray):
        self.meta = meta
        order = np.lexsort((rows, cols))
        self.rows = np.asarray(rows, np.int32)[order]
        self.cols = np.asarray(cols, np.int32)[order]
        self._check()

    # -- construction -----------------------------------------------------

    @classmethod
    def erdos_renyi(
        cls,
        meta: BlockMeta,
        density: float,
        rng: np.random.Generator,
    ) -> "BlockTopology":
        """Sample an ER block topology with ~density fraction of live blocks."""
        total = meta.total_blocks
        n_blocks = int(np.clip(round(density * total), meta.grid_n, total))
        flat = rng.choice(total, size=n_blocks, replace=False).astype(np.int64)
        rows = (flat // meta.grid_n).astype(np.int32)
        cols = (flat % meta.grid_n).astype(np.int32)
        rows, cols = _ensure_coverage(meta, rows, cols, rng)
        return cls(meta, rows, cols)

    @classmethod
    def from_epsilon(
        cls, meta: BlockMeta, epsilon: float, rng: np.random.Generator
    ) -> "BlockTopology":
        return cls.erdos_renyi(
            meta, density_from_epsilon(epsilon, meta.in_dim, meta.out_dim), rng
        )

    # -- properties ---------------------------------------------------------

    @property
    def n_blocks(self) -> int:
        return int(self.rows.shape[0])

    @property
    def density(self) -> float:
        return self.n_blocks / self.meta.total_blocks

    @property
    def n_params(self) -> int:
        return self.n_blocks * self.meta.block_m * self.meta.block_n

    def _check(self) -> None:
        m = self.meta
        assert self.rows.shape == self.cols.shape
        assert (0 <= self.rows).all() and (self.rows < m.grid_m).all()
        assert (0 <= self.cols).all() and (self.cols < m.grid_n).all()
        flat = self.rows.astype(np.int64) * m.grid_n + self.cols
        assert np.unique(flat).size == flat.size, "duplicate block positions"
        assert np.unique(self.cols).size == m.grid_n, (
            "coverage invariant violated: some output block-column has no slot"
        )

    # -- device views ---------------------------------------------------------

    def device_arrays(self) -> BlockTopoArrays:
        rows, cols = self.rows, self.cols
        perm_r = np.lexsort((cols, rows)).astype(np.int32)
        rows_r = rows[perm_r]
        cols_r = cols[perm_r]
        return BlockTopoArrays(
            rows=jnp.asarray(rows),
            cols=jnp.asarray(cols),
            first_col=jnp.asarray(_first_flags(cols)),
            rows_r=jnp.asarray(rows_r),
            cols_r=jnp.asarray(cols_r),
            first_row=jnp.asarray(_first_flags(rows_r)),
            perm_r=jnp.asarray(perm_r),
        )

    # -- values -----------------------------------------------------------

    def init_values(
        self,
        rng: np.random.Generator,
        dtype=jnp.float32,
        scheme: str = "he_uniform",
    ) -> jax.Array:
        m = self.meta
        shape = (self.n_blocks, m.block_m, m.block_n)
        vals = _init_numpy(rng, shape, fan_in_dense=m.in_dim, scheme=scheme)
        # connections that fall into the zero-padding region of a padded grid
        # must stay zero so padded inputs contribute nothing.
        return jnp.asarray(vals, dtype=dtype)

    def to_dense(self, values: jax.Array) -> jax.Array:
        """Scatter block values into the dense (in_dim, out_dim) matrix."""
        m = self.meta
        dense = jnp.zeros((m.grid_m, m.block_m, m.grid_n, m.block_n), values.dtype)
        dense = dense.at[self.rows, :, self.cols, :].set(values)
        dense = dense.reshape(m.padded_in, m.padded_out)
        return dense[: m.in_dim, : m.out_dim]


def _ensure_coverage(
    meta: BlockMeta, rows: np.ndarray, cols: np.ndarray, rng: np.random.Generator
) -> Tuple[np.ndarray, np.ndarray]:
    """Swap surplus slots into uncovered block-columns (keeps slot count)."""
    covered = np.zeros(meta.grid_n, bool)
    covered[cols] = True
    missing = np.flatnonzero(~covered)
    if missing.size == 0:
        return rows, cols
    # donate slots from columns having > 1 block
    order = np.argsort(cols, kind="stable")
    counts = np.bincount(cols, minlength=meta.grid_n)
    donors = [i for i in order if counts[cols[i]] > 1]
    if len(donors) < missing.size:
        raise ValueError(
            f"cannot cover {missing.size} empty block-columns with "
            f"{len(donors)} donor slots; raise density"
        )
    taken = set()
    di = 0
    rows = rows.copy()
    cols = cols.copy()
    for c in missing:
        while True:
            slot = donors[di]
            di += 1
            if counts[cols[slot]] > 1:
                counts[cols[slot]] -= 1
                break
        cols[slot] = c
        rows[slot] = rng.integers(meta.grid_m)
        taken.add(slot)
    # dedupe (rare): if the random row collides within the column, nudge
    flat = rows.astype(np.int64) * meta.grid_n + cols
    while np.unique(flat).size != flat.size:
        _, idx, cnt = np.unique(flat, return_index=True, return_counts=True)
        for f, i0, c0 in zip(_, idx, cnt):
            if c0 > 1:
                dup = np.flatnonzero(flat == f)[1:]
                for d in dup:
                    rows[d] = rng.integers(meta.grid_m)
        flat = rows.astype(np.int64) * meta.grid_n + cols
    return rows, cols


# ---------------------------------------------------------------------------
# Element sparsity (paper-faithful COO)
# ---------------------------------------------------------------------------


class ElemTopoArrays(NamedTuple):
    rows: jax.Array
    cols: jax.Array


class ElementTopology:
    """Host-side COO topology for the paper's SET-MLP path.

    rows/cols are int32 (nnz,) with unique positions, sorted by (col, row).
    """

    def __init__(self, in_dim: int, out_dim: int, rows: np.ndarray, cols: np.ndarray):
        self.in_dim = int(in_dim)
        self.out_dim = int(out_dim)
        order = np.lexsort((rows, cols))
        self.rows = np.asarray(rows, np.int32)[order]
        self.cols = np.asarray(cols, np.int32)[order]
        flat = self.rows.astype(np.int64) * out_dim + self.cols
        assert np.unique(flat).size == flat.size, "duplicate connections"

    @classmethod
    def erdos_renyi(
        cls, in_dim: int, out_dim: int, epsilon: float, rng: np.random.Generator
    ) -> "ElementTopology":
        nnz = erdos_renyi_nnz(epsilon, in_dim, out_dim)
        nnz = min(nnz, in_dim * out_dim)
        flat = rng.choice(in_dim * out_dim, size=nnz, replace=False).astype(np.int64)
        return cls(in_dim, out_dim, (flat // out_dim), (flat % out_dim))

    @property
    def nnz(self) -> int:
        return int(self.rows.shape[0])

    @property
    def density(self) -> float:
        return self.nnz / (self.in_dim * self.out_dim)

    def device_arrays(self) -> "ElemTopoArrays":
        return ElemTopoArrays(jnp.asarray(self.rows), jnp.asarray(self.cols))

    def init_values(
        self, rng: np.random.Generator, dtype=jnp.float32, scheme: str = "he_uniform"
    ) -> jax.Array:
        vals = _init_numpy(rng, (self.nnz,), fan_in_dense=self.in_dim, scheme=scheme)
        return jnp.asarray(vals, dtype=dtype)

    def to_dense(self, values: jax.Array) -> jax.Array:
        dense = jnp.zeros((self.in_dim, self.out_dim), values.dtype)
        return dense.at[self.rows, self.cols].set(values)


def element_spmm(
    x: jax.Array, values: jax.Array, rows: jax.Array, cols: jax.Array, out_dim: int
) -> jax.Array:
    """Truly sparse y = x @ W for COO W. FLOPs = 2 * B * nnz.

    Differentiable through the gather/scatter (XLA generates the transposed
    scatter/gather pair for the VJP, also O(B * nnz)). Materializes the full
    (batch, nnz) contribution array — kept as the simple reference; the
    memory-bounded default is ``element_spmm_segment`` (DESIGN.md §1).
    """
    contrib = x[..., rows] * values  # (..., nnz)
    out_shape = x.shape[:-1] + (out_dim,)
    y = jnp.zeros(out_shape, contrib.dtype)
    return y.at[..., cols].add(contrib)


# Largest per-chunk contribution width: peak intermediate of the segment-sum
# SpMM is (batch, SPMM_CHUNK) regardless of nnz.
SPMM_CHUNK = 8192

# "auto" impl policy: below this nnz the scatter-add formulation is faster on
# XLA:CPU (the chunked segment reduction pays scan + transpose overhead that
# only amortizes at scale), and its (batch, nnz) intermediate is still small;
# above it XLA's scatter falls off a cliff (measured ~14x slower by nnz=131k)
# and its intermediate grows unboundedly, so the segment path takes over.
SPMM_AUTO_NNZ = 65536
# ...and independently of nnz, switch to the memory-bounded segment path once
# the (batch, nnz) scatter intermediate would exceed this many elements.
SPMM_AUTO_ELEMS = 16 * 1024 * 1024


def element_spmm_segment(
    x: jax.Array,
    values: jax.Array,
    rows: jax.Array,
    cols: jax.Array,
    out_dim: int,
    *,
    chunk: Optional[int] = None,
) -> jax.Array:
    """Col-sorted segment-sum SpMM (DESIGN.md §1). Same math as
    ``element_spmm`` but the (batch, nnz) contribution array is never
    materialized at once: nnz is processed in chunks of at most ``chunk``
    columns via ``jax.ops.segment_sum`` under a ``lax.scan``, so peak
    intermediate memory is O(batch * chunk) instead of O(batch * nnz).

    Requires the canonical topology ordering (sorted by (col, row) —
    ``ElementTopology`` guarantees it), which makes every chunk's segment ids
    sorted and the segment reduction a single linear pass.
    """
    nnz = int(values.shape[0])
    if chunk is None:
        chunk = SPMM_CHUNK
    chunk = max(1, min(int(chunk), nnz))
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    dtype = jnp.result_type(x2.dtype, values.dtype)

    def one_chunk(r, c, v):
        contrib = x2[:, r] * v  # (B, chunk)
        return jax.ops.segment_sum(
            contrib.T.astype(dtype), c, num_segments=out_dim,
            indices_are_sorted=True,
        ).T  # (B, out_dim)

    n_chunks = -(-nnz // chunk)
    if n_chunks == 1:
        y = one_chunk(rows, cols, values)
    else:
        pad = n_chunks * chunk - nnz
        # padded slots: col == out_dim (dropped by segment_sum) and value 0
        rows_p = jnp.concatenate([rows, jnp.zeros((pad,), rows.dtype)])
        cols_p = jnp.concatenate([cols, jnp.full((pad,), out_dim, cols.dtype)])
        vals_p = jnp.concatenate([values, jnp.zeros((pad,), values.dtype)])
        slices = (
            rows_p.reshape(n_chunks, chunk),
            cols_p.reshape(n_chunks, chunk),
            vals_p.reshape(n_chunks, chunk),
        )

        def body(y, sl):
            return y + one_chunk(*sl), None

        y0 = jnp.zeros((x2.shape[0], out_dim), dtype)
        y, _ = jax.lax.scan(body, y0, slices)
    return y.reshape(*lead, out_dim)


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def _init_numpy(
    rng: np.random.Generator, shape, *, fan_in_dense: int, scheme: str
) -> np.ndarray:
    """Weight init. fan_in follows the paper (dense fan-in based scaling)."""
    if scheme == "normal":
        return rng.standard_normal(shape).astype(np.float32) * 0.05
    if scheme == "he_uniform":
        limit = np.sqrt(6.0 / max(1, fan_in_dense))
        return rng.uniform(-limit, limit, size=shape).astype(np.float32)
    if scheme == "xavier":
        limit = np.sqrt(3.0 / max(1, fan_in_dense))
        return rng.uniform(-limit, limit, size=shape).astype(np.float32)
    if scheme == "zeros":
        return np.zeros(shape, np.float32)
    raise ValueError(f"unknown init scheme {scheme!r}")
