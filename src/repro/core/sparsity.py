"""Truly sparse weight representations.

Two granularities, both storing ONLY the live parameters (no dense mask):

* ``ElementSparse`` — COO element-level sparsity. This is the paper-faithful
  representation (SciPy-CSR equivalent) used for the SET-MLP experiments.
  Compute is a chunked segment-sum SpMM whose FLOP count is O(B * nnz);
  topology arrays carry a dual (col,row)/(row,col) order so the hand-derived
  backward passes are segment reductions too (DESIGN.md §1b).

* ``BlockSparse`` — MXU-aligned block sparsity (TPU adaptation, see DESIGN.md
  §2). Active (block_m, block_n) tiles are stored as a compact
  ``(n_blocks, bm, bn)`` array plus int32 block coordinates. Compute goes
  through either a Pallas kernel (``repro.kernels``) or an XLA-native
  gather/segment-sum einsum whose FLOPs also scale with the live block count.

Topology (coordinates) is intentionally kept in host numpy and treated as
non-trainable data: SET evolution / Importance Pruning happen *between* jitted
train segments (the paper evolves once per epoch on the master), so the jitted
step functions only ever see fixed-capacity arrays and never recompile when
connections move.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "BlockMeta",
    "BlockTopoArrays",
    "BlockTopology",
    "ElemTopoArrays",
    "ElementTopology",
    "coo_dw",
    "coo_matmul_T",
    "density_from_epsilon",
    "element_spmm",
    "element_spmm_segment",
    "erdos_renyi_nnz",
    "spmm_chunk_for",
]


def density_from_epsilon(epsilon: float, n_in: int, n_out: int) -> float:
    """SET's Erdős–Rényi density: p = eps * (n_in + n_out) / (n_in * n_out)."""
    return min(1.0, float(epsilon) * (n_in + n_out) / (n_in * n_out))


def erdos_renyi_nnz(epsilon: float, n_in: int, n_out: int) -> int:
    return max(1, int(round(density_from_epsilon(epsilon, n_in, n_out) * n_in * n_out)))


# ---------------------------------------------------------------------------
# Block sparsity
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BlockMeta:
    """Static metadata of a block-sparse matrix (hashable; safe for jit)."""

    in_dim: int
    out_dim: int
    block_m: int = 128
    block_n: int = 128

    @property
    def grid_m(self) -> int:
        return -(-self.in_dim // self.block_m)

    @property
    def grid_n(self) -> int:
        return -(-self.out_dim // self.block_n)

    @property
    def padded_in(self) -> int:
        return self.grid_m * self.block_m

    @property
    def padded_out(self) -> int:
        return self.grid_n * self.block_n

    @property
    def total_blocks(self) -> int:
        return self.grid_m * self.grid_n


class BlockTopoArrays(NamedTuple):
    """Device-side topology for the kernels. All int32, shape (n_blocks,).

    Canonical order is sorted by (col, row) — required by the forward kernel's
    output-revisit accumulation. ``*_r`` fields are the same topology sorted by
    (row, col) for the dX kernel; ``perm_r[i]`` maps row-ordered slot i back to
    the canonical slot owning its values.
    """

    rows: jax.Array
    cols: jax.Array
    first_col: jax.Array  # 1 where cols[i] != cols[i-1]
    rows_r: jax.Array
    cols_r: jax.Array
    first_row: jax.Array  # 1 where rows_r[i] != rows_r[i-1]
    perm_r: jax.Array


def _first_flags(keys: np.ndarray) -> np.ndarray:
    first = np.ones_like(keys, dtype=np.int32)
    if keys.size > 1:
        first[1:] = (keys[1:] != keys[:-1]).astype(np.int32)
    return first


class BlockTopology:
    """Host-side (numpy) block topology with SET bookkeeping.

    Invariants:
      * slots sorted by (col, row); positions unique
      * every block-column in [0, grid_n) is covered by >= 1 slot
        ("no output neuron without incoming connections"); coverage slots may
        be zero-valued but keep the Pallas output-tile zeroing correct.
    """

    def __init__(self, meta: BlockMeta, rows: np.ndarray, cols: np.ndarray):
        self.meta = meta
        order = np.lexsort((rows, cols))
        self.rows = np.asarray(rows, np.int32)[order]
        self.cols = np.asarray(cols, np.int32)[order]
        self._check()

    # -- construction -----------------------------------------------------

    @classmethod
    def erdos_renyi(
        cls,
        meta: BlockMeta,
        density: float,
        rng: np.random.Generator,
    ) -> "BlockTopology":
        """Sample an ER block topology with ~density fraction of live blocks."""
        total = meta.total_blocks
        n_blocks = int(np.clip(round(density * total), meta.grid_n, total))
        flat = rng.choice(total, size=n_blocks, replace=False).astype(np.int64)
        rows = (flat // meta.grid_n).astype(np.int32)
        cols = (flat % meta.grid_n).astype(np.int32)
        rows, cols = _ensure_coverage(meta, rows, cols, rng)
        return cls(meta, rows, cols)

    @classmethod
    def from_epsilon(
        cls, meta: BlockMeta, epsilon: float, rng: np.random.Generator
    ) -> "BlockTopology":
        return cls.erdos_renyi(
            meta, density_from_epsilon(epsilon, meta.in_dim, meta.out_dim), rng
        )

    # -- properties ---------------------------------------------------------

    @property
    def n_blocks(self) -> int:
        return int(self.rows.shape[0])

    @property
    def density(self) -> float:
        return self.n_blocks / self.meta.total_blocks

    @property
    def n_params(self) -> int:
        return self.n_blocks * self.meta.block_m * self.meta.block_n

    def _check(self) -> None:
        m = self.meta
        assert self.rows.shape == self.cols.shape
        assert (0 <= self.rows).all() and (self.rows < m.grid_m).all()
        assert (0 <= self.cols).all() and (self.cols < m.grid_n).all()
        flat = self.rows.astype(np.int64) * m.grid_n + self.cols
        assert np.unique(flat).size == flat.size, "duplicate block positions"
        assert np.unique(self.cols).size == m.grid_n, (
            "coverage invariant violated: some output block-column has no slot"
        )

    # -- device views ---------------------------------------------------------

    def device_arrays(self) -> BlockTopoArrays:
        rows, cols = self.rows, self.cols
        perm_r = np.lexsort((cols, rows)).astype(np.int32)
        rows_r = rows[perm_r]
        cols_r = cols[perm_r]
        return BlockTopoArrays(
            rows=jnp.asarray(rows),
            cols=jnp.asarray(cols),
            first_col=jnp.asarray(_first_flags(cols)),
            rows_r=jnp.asarray(rows_r),
            cols_r=jnp.asarray(cols_r),
            first_row=jnp.asarray(_first_flags(rows_r)),
            perm_r=jnp.asarray(perm_r),
        )

    # -- values -----------------------------------------------------------

    def init_values(
        self,
        rng: np.random.Generator,
        dtype=jnp.float32,
        scheme: str = "he_uniform",
    ) -> jax.Array:
        m = self.meta
        shape = (self.n_blocks, m.block_m, m.block_n)
        vals = _init_numpy(rng, shape, fan_in_dense=m.in_dim, scheme=scheme)
        # connections that fall into the zero-padding region of a padded grid
        # must stay zero so padded inputs contribute nothing.
        return jnp.asarray(vals, dtype=dtype)

    def to_dense(self, values: jax.Array) -> jax.Array:
        """Scatter block values into the dense (in_dim, out_dim) matrix."""
        m = self.meta
        dense = jnp.zeros((m.grid_m, m.block_m, m.grid_n, m.block_n), values.dtype)
        dense = dense.at[self.rows, :, self.cols, :].set(values)
        dense = dense.reshape(m.padded_in, m.padded_out)
        return dense[: m.in_dim, : m.out_dim]


def _ensure_coverage(
    meta: BlockMeta, rows: np.ndarray, cols: np.ndarray, rng: np.random.Generator
) -> Tuple[np.ndarray, np.ndarray]:
    """Swap surplus slots into uncovered block-columns (keeps slot count)."""
    covered = np.zeros(meta.grid_n, bool)
    covered[cols] = True
    missing = np.flatnonzero(~covered)
    if missing.size == 0:
        return rows, cols
    # donate slots from columns having > 1 block
    order = np.argsort(cols, kind="stable")
    counts = np.bincount(cols, minlength=meta.grid_n)
    donors = [i for i in order if counts[cols[i]] > 1]
    if len(donors) < missing.size:
        raise ValueError(
            f"cannot cover {missing.size} empty block-columns with "
            f"{len(donors)} donor slots; raise density"
        )
    taken = set()
    di = 0
    rows = rows.copy()
    cols = cols.copy()
    for c in missing:
        while True:
            slot = donors[di]
            di += 1
            if counts[cols[slot]] > 1:
                counts[cols[slot]] -= 1
                break
        cols[slot] = c
        rows[slot] = rng.integers(meta.grid_m)
        taken.add(slot)
    # dedupe (rare): if the random row collides within the column, nudge
    flat = rows.astype(np.int64) * meta.grid_n + cols
    while np.unique(flat).size != flat.size:
        _, idx, cnt = np.unique(flat, return_index=True, return_counts=True)
        for f, i0, c0 in zip(_, idx, cnt):
            if c0 > 1:
                dup = np.flatnonzero(flat == f)[1:]
                for d in dup:
                    rows[d] = rng.integers(meta.grid_m)
        flat = rows.astype(np.int64) * meta.grid_n + cols
    return rows, cols


# ---------------------------------------------------------------------------
# Element sparsity (paper-faithful COO)
# ---------------------------------------------------------------------------


class ElemTopoArrays(NamedTuple):
    """Device-side dual-order COO topology. All int32, shape (nnz,).

    Canonical order is sorted by (col, row) — ``cols`` is non-decreasing, so
    the forward/dW passes are sorted segment reductions. The ``*_r`` fields
    are the same connections re-sorted by (row, col) for the hand-derived dX
    backward pass (``rows_r`` non-decreasing — sorted segment ids, no XLA
    scatter anywhere); ``perm_r[j]`` maps row-ordered slot j back to the
    canonical slot owning its value. ``first_col``/``first_row`` flag segment
    boundaries (1 where the sort key changes), mirroring ``BlockTopoArrays``:
    the XLA-path kernels use ``indices_are_sorted`` segment sums and don't
    read them, but a Pallas element kernel needs them for its first-visit
    output-tile zeroing exactly like the block kernels — the layouts are
    kept identical so the two granularities stay drop-in interchangeable.
    """

    rows: jax.Array
    cols: jax.Array
    first_col: jax.Array  # 1 where cols[i] != cols[i-1]
    rows_r: jax.Array
    cols_r: jax.Array
    first_row: jax.Array  # 1 where rows_r[i] != rows_r[i-1]
    perm_r: jax.Array


class ElementTopology:
    """Host-side COO topology for the paper's SET-MLP path.

    rows/cols are int32 (nnz,) with unique positions, sorted by (col, row).
    """

    def __init__(self, in_dim: int, out_dim: int, rows: np.ndarray, cols: np.ndarray):
        self.in_dim = int(in_dim)
        self.out_dim = int(out_dim)
        order = np.lexsort((rows, cols))
        self.rows = np.asarray(rows, np.int32)[order]
        self.cols = np.asarray(cols, np.int32)[order]
        flat = self.rows.astype(np.int64) * out_dim + self.cols
        assert np.unique(flat).size == flat.size, "duplicate connections"

    @classmethod
    def erdos_renyi(
        cls, in_dim: int, out_dim: int, epsilon: float, rng: np.random.Generator
    ) -> "ElementTopology":
        nnz = erdos_renyi_nnz(epsilon, in_dim, out_dim)
        nnz = min(nnz, in_dim * out_dim)
        flat = rng.choice(in_dim * out_dim, size=nnz, replace=False).astype(np.int64)
        return cls(in_dim, out_dim, (flat // out_dim), (flat % out_dim))

    @property
    def nnz(self) -> int:
        return int(self.rows.shape[0])

    @property
    def density(self) -> float:
        return self.nnz / (self.in_dim * self.out_dim)

    def device_arrays(self) -> "ElemTopoArrays":
        rows, cols = self.rows, self.cols
        perm_r = np.lexsort((cols, rows)).astype(np.int32)
        rows_r = rows[perm_r]
        cols_r = cols[perm_r]
        return ElemTopoArrays(
            rows=jnp.asarray(rows),
            cols=jnp.asarray(cols),
            first_col=jnp.asarray(_first_flags(cols)),
            rows_r=jnp.asarray(rows_r),
            cols_r=jnp.asarray(cols_r),
            first_row=jnp.asarray(_first_flags(rows_r)),
            perm_r=jnp.asarray(perm_r),
        )

    def init_values(
        self, rng: np.random.Generator, dtype=jnp.float32, scheme: str = "he_uniform"
    ) -> jax.Array:
        vals = _init_numpy(rng, (self.nnz,), fan_in_dense=self.in_dim, scheme=scheme)
        return jnp.asarray(vals, dtype=dtype)

    def to_dense(self, values: jax.Array) -> jax.Array:
        dense = jnp.zeros((self.in_dim, self.out_dim), values.dtype)
        return dense.at[self.rows, self.cols].set(values)


def element_spmm(
    x: jax.Array, values: jax.Array, rows: jax.Array, cols: jax.Array, out_dim: int
) -> jax.Array:
    """Truly sparse y = x @ W for COO W. FLOPs = 2 * B * nnz.

    Reference/fallback formulation only. It materializes the full
    (batch, nnz) contribution array, and — worse — its autodiff VJP is the
    transposed scatter/gather pair XLA emits: the dX path scatters with
    *unsorted* row indices (the scatter cliff ``BENCH_kernels.json`` measures
    at 3–14x beyond ~65k nnz) and re-materializes the (batch, nnz)
    contribution array again on the way back. The memory-bounded default for
    training is the hand-derived custom-VJP path (``kernels.ops.espmm`` with
    ``impl="custom"``; DESIGN.md §1 "Backward"), whose three passes all peak
    at O(batch * chunk).
    """
    contrib = x[..., rows] * values  # (..., nnz)
    out_shape = x.shape[:-1] + (out_dim,)
    y = jnp.zeros(out_shape, contrib.dtype)
    return y.at[..., cols].add(contrib)


# Batch-aware chunk policy: instead of a fixed width, target a fixed
# (batch * chunk) temp-element budget so the peak intermediate of every
# chunked pass (fwd / dX / dW) is the same number of bytes whatever the
# batch. 2M f32 elements = 8 MiB per temp; at the benchmark's B=256 this
# reproduces the previous fixed chunk of 8192.
SPMM_TEMP_BUDGET_ELEMS = 2 * 1024 * 1024
# Floor so tiny batches don't degenerate into thousands of scan steps.
SPMM_CHUNK_MIN = 512

# "auto" impl policy for ``kernels.ops.espmm`` — calibrated on
# jax.value_and_grad wall clock (fwd + dX + dW), not forward-only: the
# scatter formulation's autodiff backward hits the unsorted-scatter cliff
# far earlier and harder than its forward (measured on XLA:CPU at B=256:
# custom/scatter value_and_grad speedup 1.2x by nnz=1k, 2.1x by 4k, 5x by
# 65k, 15x by 262k), so the crossover sits two orders of magnitude below
# the old forward-only fit of 65536. Below this nnz the scatter-add
# formulation still wins the *forward* (eval shares this dispatch), its
# (batch, nnz) intermediate is still tiny, and its backward deficit is
# ~20% — above it the custom-VJP path wins both directions outright
# (benchmarks/kernels_micro.py tracks fwd and value_and_grad rows).
SPMM_AUTO_NNZ = 2048
# ...and independently of nnz, switch to the memory-bounded custom-VJP path
# once the (batch, nnz) scatter intermediate (which autodiff re-materializes
# on the backward pass too) would exceed this many elements.
SPMM_AUTO_ELEMS = 512 * 1024

# Forward-only "auto" policy for serving (``kernels.ops.espmm_infer``).
# Inference never runs a backward pass, so the value_and_grad-calibrated
# thresholds above are wrong for it: the scatter formulation's *forward*
# stays ahead of the chunked segment path until far larger problems (the
# PR-1 forward-only fit measured the crossover near 65k nnz on XLA:CPU —
# the scatter cliff the training thresholds dodge is a backward artifact).
# Serving still bounds peak temp memory: beyond SPMM_INFER_ELEMS elements
# the (batch, nnz) scatter intermediate would exceed the budget, so the
# chunked segment path takes over regardless of nnz.
SPMM_INFER_NNZ = 65536
SPMM_INFER_ELEMS = 4 * 1024 * 1024


def spmm_chunk_for(batch: int, nnz: int, chunk: Optional[int] = None) -> int:
    """Chunk width for the chunked element passes.

    ``chunk=None`` picks the batch-aware width targeting
    ``SPMM_TEMP_BUDGET_ELEMS`` temp elements; an explicit ``chunk`` is only
    clamped to [1, nnz].
    """
    if chunk is None:
        chunk = max(SPMM_CHUNK_MIN, SPMM_TEMP_BUDGET_ELEMS // max(1, int(batch)))
    return max(1, min(int(chunk), max(1, int(nnz))))


def element_spmm_segment(
    x: jax.Array,
    values: jax.Array,
    rows: jax.Array,
    cols: jax.Array,
    out_dim: int,
    *,
    chunk: Optional[int] = None,
) -> jax.Array:
    """Col-sorted segment-sum SpMM (DESIGN.md §1). Same math as
    ``element_spmm`` but the (batch, nnz) contribution array is never
    materialized at once: a thin wrapper over :func:`coo_matmul_T` (the
    shared chunked sorted-segment reduction), so peak intermediate memory is
    O(batch * chunk) instead of O(batch * nnz).

    Differentiable by XLA autodiff — but autodiff through the scan saves a
    residual slab per chunk (O(batch * nnz) again); training goes through
    the hand-derived custom VJP in ``kernels.ops`` instead, which reuses the
    same primitive for its dX pass over the row-sorted dual order.

    Requires the canonical topology ordering (sorted by (col, row) —
    ``ElementTopology`` guarantees it), which makes every chunk's segment ids
    sorted and the segment reduction a single linear pass.

    ``chunk=None`` picks the batch-aware width (``spmm_chunk_for``).
    """
    nnz = int(values.shape[0])
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    dtype = jnp.result_type(x2.dtype, values.dtype)
    if nnz == 0:  # explicit: no connections -> zero output, no scan
        return jnp.zeros((*lead, out_dim), dtype)
    yT = coo_matmul_T(x2.T, values, rows, cols, out_dim, chunk=chunk)
    return yT.T.reshape(*lead, out_dim)


# ---------------------------------------------------------------------------
# transpose-free chunked passes (DESIGN.md §1 "Backward")
#
# The three passes of the hand-derived espmm VJP are the same primitive:
# a chunked sorted-segment reduction computed in transposed (features, batch)
# layout, so the only layout changes are one transpose of the operand on the
# way in and one of the result on the way out — never per chunk.
# ---------------------------------------------------------------------------


def coo_matmul_T(
    srcT: jax.Array,
    values: jax.Array,
    gather_idx: jax.Array,
    segment_idx: jax.Array,
    n_segments: int,
    *,
    chunk: Optional[int] = None,
    acc: Optional[jax.Array] = None,
) -> jax.Array:
    """``accT[segment_idx[j], :] += srcT[gather_idx[j], :] * values[j]``.

    ``srcT`` is (src_dim, B); returns (n_segments, B). ``segment_idx`` must be
    non-decreasing — the canonical (col, row) order for the forward
    (gather rows, segment cols) and the row-sorted dual order for dX
    (gather cols_r, segment rows_r) both guarantee it — so every chunk's
    ``segment_sum`` is one sorted linear pass, no scatter. Peak intermediate
    is the (chunk, B) contribution slab; nnz is walked by a ``lax.scan``.

    ``acc`` (optional, (n_segments, B)) is a carry-in accumulator: the result
    is ``acc`` plus this call's reduction, added chunk-by-chunk in the same
    left-to-right order the single-call path uses. The out-of-core substrate
    (``kernels.ops.xl_shard_acc``, DESIGN.md §7) threads one accumulator
    through a connection-shard stream; when shard boundaries are multiples of
    ``chunk``, the chunk partition — and therefore the f32 addition order —
    is identical to one in-core call over the concatenated shards.
    """
    nnz = int(values.shape[0])
    B = srcT.shape[-1]
    dtype = jnp.result_type(srcT.dtype, values.dtype)
    if nnz == 0:
        return acc if acc is not None else jnp.zeros((n_segments, B), dtype)
    chunk = spmm_chunk_for(B, nnz, chunk)

    def one_chunk(g, s, v):
        contrib = srcT[g, :] * v[:, None]  # (chunk, B) — already transposed
        return jax.ops.segment_sum(
            contrib.astype(dtype), s, num_segments=n_segments,
            indices_are_sorted=True,
        )

    n_chunks = -(-nnz // chunk)
    if n_chunks == 1:
        one = one_chunk(gather_idx, segment_idx, values)
        return one if acc is None else acc + one
    pad = n_chunks * chunk - nnz
    # padded slots: segment id == n_segments (dropped by segment_sum, and
    # >= every real id so per-chunk sortedness holds) and value 0
    g_p = jnp.concatenate([gather_idx, jnp.zeros((pad,), gather_idx.dtype)])
    s_p = jnp.concatenate(
        [segment_idx, jnp.full((pad,), n_segments, segment_idx.dtype)]
    )
    v_p = jnp.concatenate([values, jnp.zeros((pad,), values.dtype)])
    slices = (
        g_p.reshape(n_chunks, chunk),
        s_p.reshape(n_chunks, chunk),
        v_p.reshape(n_chunks, chunk),
    )

    def body(a, sl):
        return a + one_chunk(*sl), None

    acc0 = jnp.zeros((n_segments, B), dtype) if acc is None else acc
    out, _ = jax.lax.scan(body, acc0, slices)
    return out


def coo_dw(
    xT: jax.Array,
    dyT: jax.Array,
    rows: jax.Array,
    cols: jax.Array,
    *,
    chunk: Optional[int] = None,
) -> jax.Array:
    """Per-slot batch contraction ``dv[j] = sum_b x[b, rows[j]] * dy[b, cols[j]]``.

    ``xT`` is (in_dim, B), ``dyT`` is (out_dim, B); returns (nnz,) aligned to
    the canonical slot order. Chunked like :func:`coo_matmul_T`: the two
    gathered (chunk, B) slabs are the peak intermediate, reduced over batch
    immediately — the (batch, nnz) contribution array is never materialized.
    """
    nnz = int(rows.shape[0])
    dtype = jnp.result_type(xT.dtype, dyT.dtype)
    if nnz == 0:
        return jnp.zeros((0,), dtype)
    chunk = spmm_chunk_for(xT.shape[-1], nnz, chunk)

    def one_chunk(r, c):
        return (xT[r, :].astype(dtype) * dyT[c, :].astype(dtype)).sum(axis=-1)

    n_chunks = -(-nnz // chunk)
    if n_chunks == 1:
        return one_chunk(rows, cols)
    pad = n_chunks * chunk - nnz
    # padded slots gather slot 0 — their outputs are sliced off below
    r_p = jnp.concatenate([rows, jnp.zeros((pad,), rows.dtype)])
    c_p = jnp.concatenate([cols, jnp.zeros((pad,), cols.dtype)])

    def body(carry, sl):
        return carry, one_chunk(*sl)

    _, dv = jax.lax.scan(
        body, 0, (r_p.reshape(n_chunks, chunk), c_p.reshape(n_chunks, chunk))
    )
    return dv.reshape(-1)[:nnz]


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def _init_numpy(
    rng: np.random.Generator, shape, *, fan_in_dense: int, scheme: str
) -> np.ndarray:
    """Weight init. fan_in follows the paper (dense fan-in based scaling)."""
    if scheme == "normal":
        return rng.standard_normal(shape).astype(np.float32) * 0.05
    if scheme == "he_uniform":
        limit = np.sqrt(6.0 / max(1, fan_in_dense))
        return rng.uniform(-limit, limit, size=shape).astype(np.float32)
    if scheme == "xavier":
        limit = np.sqrt(3.0 / max(1, fan_in_dense))
        return rng.uniform(-limit, limit, size=shape).astype(np.float32)
    if scheme == "zeros":
        return np.zeros(shape, np.float32)
    raise ValueError(f"unknown init scheme {scheme!r}")
