"""Central buffer-donation policy for every hot-path jit.

One place decides whether ``donate_argnums`` is requested, instead of the
``(0, 1) if jax.default_backend() != "cpu" else ()`` expression previously
copy-pasted across trainer/wasap/ops/engine.  The policy:

* accelerators — donate: params/optimizer/cache buffers are updated in place,
  which is what keeps the fused epoch and the decode loop allocation-flat.
* CPU — don't donate.  CPU XLA *does* implement input/output aliasing on
  current jaxlibs (it was a warn-and-ignore no-op when these call sites were
  first written), but the CI benchmarks and equivalence tests deliberately
  re-run several implementations from the same initial buffers; donation
  would invalidate those arrays after the first call.  Keeping CPU
  conservative preserves that, and costs nothing the CI measures.

The hot-path contract auditor (``repro.analysis``) does NOT trust this
policy: every builder that takes buffers it should donate accepts an explicit
``donate=`` override, and the audit force-builds a donated variant and
verifies in the compiled HLO that input/output aliasing actually happened
(DESIGN.md §10).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax


def backend_donates() -> bool:
    """Whether the repo policy requests donation on this backend."""
    return jax.default_backend() != "cpu"


def donate_argnums(
    *argnums: int, override: Optional[Tuple[int, ...]] = None
) -> Tuple[int, ...]:
    """The ``donate_argnums`` tuple for a hot-path jit.

    ``override`` short-circuits the policy: builders thread their ``donate=``
    parameter through here so the auditor (and tests) can force donation on
    (to machine-check aliasing) or off (to keep double-call compile-count
    probes safe) regardless of backend. ``None`` means "apply the policy".
    """
    if override is not None:
        return tuple(override)
    return tuple(argnums) if backend_donates() else ()
