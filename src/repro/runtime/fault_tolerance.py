"""Fault tolerance & elasticity for multi-pod training (failure model,
recovery protocol and trajectory-equivalence argument: DESIGN.md §8,
"Failure model & recovery"; checkpoint-restore mechanics are DESIGN.md §5).

Pieces:
  * HeartbeatMonitor — per-worker liveness with deadlines; `classify()` is a
    pure read of heartbeat ages, `tick()` advances the miss window and
    performs evictions (driver-side; in a real deployment heartbeats arrive
    over the coordination service).
  * StragglerPolicy — WASAP-inspired mitigation: a straggler's contribution
    is *stale but valid* (RetainValidUpdates) rather than blocking the sync
    point; beyond `evict_after` missed beats the worker is evicted and the
    run goes elastic.
  * ElasticPlan — recompute the mesh when the healthy-device count changes:
    keep the model axis fixed (TP degree is a property of the model), shrink
    the data axis to the largest supported size, and rescale global batch.
    Restore is checkpoint-based: CheckpointManager manifests carry sharding
    metadata, so arrays re-shard onto the new mesh on load.
  * retry_step — transient-failure wrapper (preemption/ICI flap): retries a
    step function with exponential backoff, reloading from the latest
    checkpoint on persistent failure.

Fault injection for these paths lives in `runtime/faultinject.py`; the
crash-resume loop that consumes them is `runtime/supervisor.py`.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Tuple

__all__ = [
    "HeartbeatMonitor",
    "StragglerPolicy",
    "ElasticPlan",
    "plan_elastic_mesh",
    "retry_step",
]


@dataclasses.dataclass
class StragglerPolicy:
    soft_deadline_s: float = 30.0     # beyond this: straggling (don't block)
    hard_deadline_s: float = 300.0    # beyond this: dead
    evict_after: int = 3              # consecutive hard misses -> evict


class HeartbeatMonitor:
    def __init__(self, worker_ids: List[str], policy: StragglerPolicy,
                 clock: Callable[[], float] = time.monotonic):
        self.policy = policy
        self.clock = clock
        now = clock()
        self.last_beat: Dict[str, float] = {w: now for w in worker_ids}
        self.misses: Dict[str, int] = {w: 0 for w in worker_ids}
        self.evicted: set = set()

    def beat(self, worker_id: str) -> None:
        if worker_id in self.evicted:
            return
        self.last_beat[worker_id] = self.clock()
        self.misses[worker_id] = 0

    def classify(self) -> Dict[str, str]:
        """Pure read: worker -> healthy/straggling/dead/evicted from current
        heartbeat ages. Safe to poll at any frequency — state only advances
        via `beat()` and `tick()`."""
        now = self.clock()
        out = {}
        for w, t in self.last_beat.items():
            if w in self.evicted:
                out[w] = "evicted"
                continue
            age = now - t
            if age > self.policy.hard_deadline_s:
                out[w] = "dead"
            elif age > self.policy.soft_deadline_s:
                out[w] = "straggling"
            else:
                out[w] = "healthy"
        return out

    def tick(self) -> Dict[str, str]:
        """One monitoring interval: charge a miss to every worker past the
        hard deadline, restart its window, evict at `evict_after` consecutive
        misses. Returns the classification as of this tick ("dead" for a
        worker whose miss was just charged, "evicted" once the count trips).
        Call once per poll cycle; `classify()` between ticks never inflates
        miss counts."""
        now = self.clock()
        out = self.classify()
        for w, status in out.items():
            if status != "dead":
                continue
            self.misses[w] += 1
            self.last_beat[w] = now  # restart the window
            if self.misses[w] >= self.policy.evict_after:
                self.evicted.add(w)
                out[w] = "evicted"
        return out

    @property
    def healthy_count(self) -> int:
        return sum(1 for s in self.classify().values() if s in ("healthy", "straggling"))


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    data: int
    model: int
    pods: int
    global_batch: int
    note: str

    @property
    def n_devices(self) -> int:
        return self.data * self.model * max(1, self.pods)


def plan_elastic_mesh(
    healthy_devices: int,
    *,
    model_axis: int = 16,
    per_replica_batch: int = 16,
    min_data: int = 1,
) -> ElasticPlan:
    """Largest (pods*data) x model mesh that fits the healthy device count.
    Model axis is preserved (resharding TP state is cheap only along data)."""
    if healthy_devices < model_axis * min_data:
        raise RuntimeError(
            f"only {healthy_devices} healthy devices; need >= {model_axis * min_data}"
        )
    data_total = healthy_devices // model_axis
    # prefer powers of two for collective efficiency
    d = 1
    while d * 2 <= data_total:
        d *= 2
    pods, data = (d // 16, 16) if d >= 32 else (1, d)
    return ElasticPlan(
        data=data,
        model=model_axis,
        pods=pods,
        global_batch=d * per_replica_batch,
        note=f"elastic: {healthy_devices} healthy -> mesh ({pods}x{data}x{model_axis})",
    )


def retry_step(
    fn: Callable,
    *args,
    retries: int = 3,
    backoff_s: float = 0.1,
    on_failure: Optional[Callable[[int, BaseException], None]] = None,
    sleep: Callable[[float], None] = time.sleep,
):
    """Run fn with retry/backoff; on_failure(attempt, err) between attempts
    (e.g. to restore from checkpoint or rebuild the mesh)."""
    err: Optional[BaseException] = None
    for attempt in range(retries + 1):
        try:
            return fn(*args)
        except Exception as e:  # noqa: BLE001
            err = e
            if on_failure is not None:
                on_failure(attempt, e)
            if attempt < retries:
                sleep(backoff_s * (2 ** attempt))
    raise err
