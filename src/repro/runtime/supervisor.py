"""Crash recovery and fault tolerance for the resumable trainers
(DESIGN.md §8: failure model, recovery protocol, trajectory equivalence).

Two layers live here. The *run loop*: ``run_supervised(trainer, config)``
wraps any trainer exposing the resume surface (``SequentialTrainer``,
``XLTrainer``; WASAP via its own phase-wise checkpointing) with the
recovery protocol. The *fault-tolerance primitives* it and the distributed
substrate consume: ``retry_step`` (transient retry with backoff),
``HeartbeatMonitor``/``StragglerPolicy`` (liveness + WASAP-style straggler
mitigation) and ``plan_elastic_mesh``/``ElasticPlan`` (mesh recomputation
when the healthy device count changes). The serving-side counterpart of
this failure model — deadlines, load shedding, circuit breaking — is
``serve/gateway.py`` (DESIGN.md §9).

The recovery protocol:

  1. **Restore** — if the checkpoint dir holds any step dirs, rewind the
     trainer to the newest checkpoint that passes integrity verification
     (``CheckpointManager.latest_valid_step`` — corrupt/partial ones are
     quarantined, the scan falls back past them).
  2. **Checkpoint on cadence** — every ``save_every_epochs`` epoch
     boundaries (and always at the final epoch), the trainer's full resume
     state is snapshotted; the write is atomic, so a kill mid-save leaves
     only a tmp dir the next manager init sweeps.
  3. **Retry transients** — steps run under ``retry_step`` (below;
     ``step_retries`` attempts with backoff) so a transient failure costs a
     retry, not the run.
  4. **Report progress** — ``progress_file`` (atomic tmp+rename) carries
     "gstep epoch" for an external watcher; ``faultinject.wait_and_kill``
     polls it to SIGKILL the process at a deterministic step.

Trajectory equivalence (the §8 contract): because a checkpoint carries every
source of randomness (data-order seed + epoch counter, jax key, numpy
bit-generator state) plus params/velocity/topology, a kill at any step
resumes from the last epoch boundary and replays the identical trajectory —
bit-exact on the in-core paths, and the streamed XL path round-trips float32
exactly too. Work lost per kill is bounded by the checkpoint cadence.

The module is runnable (``python -m repro.runtime.supervisor``) as a small
deterministic SET-MLP training driver: the subprocess target for the
resilience tests, the CI smoke job and the recovery benchmark. It seeds its
own synthetic dataset, so two invocations with the same flags train the
same run — one uninterrupted, one killed and resumed.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from pathlib import Path
from typing import Callable, Dict, List, Optional

from repro.checkpoint.manager import CheckpointManager
from repro import obs
from repro.obs import detect

__all__ = [
    "ElasticPlan",
    "HeartbeatMonitor",
    "StragglerPolicy",
    "SupervisorConfig",
    "plan_elastic_mesh",
    "read_progress",
    "retry_step",
    "run_supervised",
    "write_progress",
]


# ---------------------------------------------------------------------------
# fault-tolerance primitives (failure model & recovery: DESIGN.md §8;
# checkpoint-restore mechanics: §5)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class StragglerPolicy:
    """WASAP-inspired mitigation: a straggler's contribution is *stale but
    valid* (RetainValidUpdates) rather than blocking the sync point; beyond
    ``evict_after`` missed beats the worker is evicted and the run goes
    elastic."""

    soft_deadline_s: float = 30.0     # beyond this: straggling (don't block)
    hard_deadline_s: float = 300.0    # beyond this: dead
    evict_after: int = 3              # consecutive hard misses -> evict


class HeartbeatMonitor:
    """Per-worker liveness with deadlines; ``classify()`` is a pure read of
    heartbeat ages, ``tick()`` advances the miss window and performs
    evictions (driver-side; in a real deployment heartbeats arrive over the
    coordination service)."""

    def __init__(self, worker_ids: List[str], policy: StragglerPolicy,
                 clock: Callable[[], float] = time.monotonic):
        self.policy = policy
        self.clock = clock
        now = clock()
        self.last_beat: Dict[str, float] = {w: now for w in worker_ids}
        self.misses: Dict[str, int] = {w: 0 for w in worker_ids}
        self.evicted: set = set()

    def beat(self, worker_id: str) -> None:
        if worker_id in self.evicted:
            return
        self.last_beat[worker_id] = self.clock()
        self.misses[worker_id] = 0

    def classify(self) -> Dict[str, str]:
        """Pure read: worker -> healthy/straggling/dead/evicted from current
        heartbeat ages. Safe to poll at any frequency — state only advances
        via `beat()` and `tick()`."""
        now = self.clock()
        out = {}
        for w, t in self.last_beat.items():
            if w in self.evicted:
                out[w] = "evicted"
                continue
            age = now - t
            if age > self.policy.hard_deadline_s:
                out[w] = "dead"
            elif age > self.policy.soft_deadline_s:
                out[w] = "straggling"
            else:
                out[w] = "healthy"
        return out

    def tick(self) -> Dict[str, str]:
        """One monitoring interval: charge a miss to every worker past the
        hard deadline, restart its window, evict at `evict_after` consecutive
        misses. Returns the classification as of this tick ("dead" for a
        worker whose miss was just charged, "evicted" once the count trips).
        Call once per poll cycle; `classify()` between ticks never inflates
        miss counts."""
        now = self.clock()
        out = self.classify()
        for w, status in out.items():
            if status != "dead":
                continue
            self.misses[w] += 1
            self.last_beat[w] = now  # restart the window
            if self.misses[w] >= self.policy.evict_after:
                self.evicted.add(w)
                out[w] = "evicted"
                obs.point(
                    "supervisor.evict", worker=w, misses=self.misses[w]
                )
        return out

    @property
    def healthy_count(self) -> int:
        return sum(1 for s in self.classify().values()
                   if s in ("healthy", "straggling"))


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    data: int
    model: int
    pods: int
    global_batch: int
    note: str

    @property
    def n_devices(self) -> int:
        return self.data * self.model * max(1, self.pods)


def plan_elastic_mesh(
    healthy_devices: int,
    *,
    model_axis: int = 16,
    per_replica_batch: int = 16,
    min_data: int = 1,
) -> ElasticPlan:
    """Largest (pods*data) x model mesh that fits the healthy device count.
    Model axis is preserved (resharding TP state is cheap only along data);
    the data axis shrinks to the largest supported size and the global batch
    rescales. Restore is checkpoint-based: CheckpointManager manifests carry
    sharding metadata, so arrays re-shard onto the new mesh on load."""
    if healthy_devices < model_axis * min_data:
        raise RuntimeError(
            f"only {healthy_devices} healthy devices; "
            f"need >= {model_axis * min_data}"
        )
    data_total = healthy_devices // model_axis
    # prefer powers of two for collective efficiency
    d = 1
    while d * 2 <= data_total:
        d *= 2
    pods, data = (d // 16, 16) if d >= 32 else (1, d)
    return ElasticPlan(
        data=data,
        model=model_axis,
        pods=pods,
        global_batch=d * per_replica_batch,
        note=(
            f"elastic: {healthy_devices} healthy -> "
            f"mesh ({pods}x{data}x{model_axis})"
        ),
    )


def retry_step(
    fn: Callable,
    *args,
    retries: int = 3,
    backoff_s: float = 0.1,
    on_failure: Optional[Callable[[int, BaseException], None]] = None,
    sleep: Callable[[float], None] = time.sleep,
):
    """Run fn with retry/backoff; on_failure(attempt, err) between attempts
    (e.g. to restore from checkpoint or rebuild the mesh)."""
    err: Optional[BaseException] = None
    for attempt in range(retries + 1):
        try:
            return fn(*args)
        except Exception as e:  # noqa: BLE001
            err = e
            obs.point(
                "supervisor.retry",
                attempt=attempt,
                error=type(e).__name__,
                final=attempt >= retries,
            )
            if on_failure is not None:
                on_failure(attempt, e)
            if attempt < retries:
                sleep(backoff_s * (2 ** attempt))
    raise err


@dataclasses.dataclass
class SupervisorConfig:
    checkpoint_dir: str
    save_every_epochs: int = 1
    keep_last: int = 3
    async_write: bool = False      # sync writes: a published step is durable
    step_retries: int = 2
    retry_backoff_s: float = 0.0
    progress_file: Optional[str] = None


def write_progress(path: Optional[str], gstep: int, epoch: int) -> None:
    """Atomic progress record — readable mid-kill.

    Line 1: ``gstep epoch heartbeat last_span``. The first two fields keep
    the historical contract (``faultinject.wait_and_kill`` reads
    ``split()[0]``); the heartbeat is a monotonic timestamp so an external
    watcher can tell "slow step" from "hung process" by its age, and
    ``last_span`` is the innermost open obs span (``-`` when tracing is off)
    so a post-mortem of a kill knows *where* the run was.

    When an anomaly monitor is installed (``obs.detect.configure``), line 2
    carries its health block as one JSON object —
    ``{"latest_probe_snapshot", "active_alerts"}`` (DESIGN.md §12) — so the
    watcher that already polls this file sees training-dynamics pathologies
    (dead layer, gradient explosion, churn collapse) without touching the
    timeline store. Watchers reading only line 1 are unaffected.
    """
    if path is None:
        return
    span = obs.current_span_name("-").replace(" ", "_")
    body = f"{gstep} {epoch} {time.monotonic():.6f} {span}\n"
    health = detect.health_block()
    if health is not None:
        body += json.dumps(health, default=float) + "\n"
    p = Path(path)
    tmp = p.with_suffix(p.suffix + ".tmp")
    tmp.write_text(body)
    os.replace(tmp, p)


def read_progress(path: str) -> Dict:
    """Parse :func:`write_progress` output (the historical 2-field line,
    the 4-field line, and the optional line-2 health block)."""
    lines = Path(path).read_text().splitlines()
    fields = lines[0].split() if lines else []
    out: Dict = {"gstep": int(fields[0]), "epoch": int(fields[1])}
    if len(fields) >= 3:
        out["heartbeat"] = float(fields[2])
    if len(fields) >= 4:
        out["last_span"] = fields[3]
    rest = "".join(lines[1:]).strip()
    if rest:
        out["health"] = json.loads(rest)
    return out


def run_supervised(trainer, config: SupervisorConfig) -> Dict:
    """Run a resumable trainer under the recovery protocol. Returns
    ``{"history", "resumed_from_step", "manager"}``; call it again on a fresh
    trainer after a crash and it continues from the last valid checkpoint."""
    manager = CheckpointManager(
        config.checkpoint_dir,
        keep_last=config.keep_last,
        async_write=config.async_write,
    )
    resumed_from: Optional[int] = None
    if manager.all_steps():
        try:
            resumed_from = trainer.restore_checkpoint(manager)
            obs.point(
                "supervisor.restore",
                step=resumed_from,
                epoch_next=int(trainer.epoch_next),
            )
        except FileNotFoundError:
            # every existing checkpoint was corrupt: cold start
            obs.point("supervisor.cold_start", reason="no_valid_checkpoint")
    trainer.step_retries = config.step_retries
    trainer.retry_backoff_s = config.retry_backoff_s

    user_fault_hook = trainer.fault_hook
    user_epoch_hook = trainer.epoch_end_hook

    def on_step(gstep):
        # progress first: the watcher must see the step even if the
        # injected fault kills us right after
        write_progress(config.progress_file, gstep, trainer.epoch_next)
        if user_fault_hook is not None:
            user_fault_hook(gstep)

    def on_epoch_end(tr, epoch):
        last = epoch == tr.tc.epochs - 1
        if (epoch + 1) % config.save_every_epochs == 0 or last:
            tr.save_checkpoint(manager)
            obs.point("supervisor.checkpoint", step=tr.gstep, epoch=epoch)
        write_progress(config.progress_file, tr.gstep, tr.epoch_next)
        if user_epoch_hook is not None:
            user_epoch_hook(tr, epoch)

    trainer.fault_hook = on_step
    trainer.epoch_end_hook = on_epoch_end
    try:
        history = trainer.run()
    finally:
        trainer.fault_hook = user_fault_hook
        trainer.epoch_end_hook = user_epoch_hook
    manager.wait()
    return {
        "history": history,
        "resumed_from_step": resumed_from,
        "manager": manager,
    }


# ---------------------------------------------------------------------------
# subprocess driver — resilience tests / CI smoke / recovery benchmark
# ---------------------------------------------------------------------------


def _build_trainer(args):
    import numpy as np

    from repro.data.synthetic import Dataset, make_classification
    from repro.models.mlp import SparseMLP, SparseMLPConfig
    from repro.train.trainer import SequentialTrainer, TrainerConfig

    rng = np.random.default_rng(args.data_seed)
    x, y = make_classification(
        args.n_train + args.n_test, args.n_features,
        n_informative=8, n_redundant=8, n_classes=args.n_classes, rng=rng,
    )
    data = Dataset(
        "supervised-smoke",
        x[: args.n_train].astype(np.float32), y[: args.n_train],
        x[args.n_train :].astype(np.float32), y[args.n_train :],
        args.n_classes,
    )
    cfg = SparseMLPConfig(
        layer_dims=(args.n_features, 64, 64, args.n_classes),
        epsilon=8, dropout=0.2,
    )
    tc = TrainerConfig(
        epochs=args.epochs, batch_size=args.batch_size, evolve=True,
        seed=args.seed, fused_epochs=not args.per_batch,
        probe=getattr(args, "probe", False),
    )
    return SequentialTrainer(SparseMLP(cfg, seed=args.seed), data, tc)


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        description="Supervised (crash-recoverable) SET-MLP training run"
    )
    ap.add_argument("--ckpt", required=True, help="checkpoint directory")
    ap.add_argument("--out", help="write final history JSON here")
    ap.add_argument("--progress-file", default=None)
    ap.add_argument("--epochs", type=int, default=2)
    ap.add_argument("--batch-size", type=int, default=64)
    ap.add_argument("--seed", type=int, default=3)
    ap.add_argument("--data-seed", type=int, default=0)
    ap.add_argument("--n-train", type=int, default=512)
    ap.add_argument("--n-test", type=int, default=128)
    ap.add_argument("--n-features", type=int, default=32)
    ap.add_argument("--n-classes", type=int, default=5)
    ap.add_argument("--save-every-epochs", type=int, default=1)
    ap.add_argument(
        "--per-batch", action="store_true",
        help="per-batch stepping (fault hook fires every minibatch, so a "
        "kill lands genuinely mid-epoch)",
    )
    ap.add_argument(
        "--probe", action="store_true",
        help="enable training-dynamics probes + anomaly monitor; the "
        "progress file gains the line-2 health block (DESIGN.md §12)",
    )
    ap.add_argument(
        "--timeline", default=None,
        help="with --probe: record probe snapshots to this JSONL timeline "
        "(render with `python -m repro.obs report`)",
    )
    ap.add_argument(
        "--probe-pathology", default=None,
        choices=("dead_layer", "explode"),
        help="with --probe: corrupt the probe stream on the way to the "
        "detectors (layer-0 stats zeroed / grad norms scaled 1e6) — fault "
        "injection for the anomaly-detection path, same spirit as "
        "--kill-at-step for the recovery path",
    )
    ap.add_argument(
        "--kill-at-step", type=int, default=None,
        help="self-SIGKILL when the global step counter reaches this value",
    )
    ap.add_argument(
        "--transient-at-step", type=int, action="append", default=None,
        help="inject a transient step failure (recovered by retry_step)",
    )
    args = ap.parse_args(argv)

    trainer = _build_trainer(args)

    hooks = []
    if args.kill_at_step is not None:
        from repro.runtime.faultinject import KillSwitch

        hooks.append(KillSwitch(args.kill_at_step))
    injector = None
    if args.transient_at_step:
        from repro.runtime.faultinject import TransientFaultInjector

        injector = TransientFaultInjector(args.transient_at_step)
        hooks.append(injector)
    if hooks:
        def fault_hook(gstep):
            for h in hooks:
                h(gstep)

        trainer.fault_hook = fault_hook

    import contextlib

    monitor = None
    with contextlib.ExitStack() as stack:
        if args.probe:
            from repro.obs import probes, timeline

            monitor = detect.configure(detect.AnomalyMonitor())
            stack.callback(detect.configure, None)
            if args.probe_pathology is not None:
                stack.callback(probes.set_snapshot_transform, None)
                probes.set_snapshot_transform(
                    probes.zero_layer_transform()
                    if args.probe_pathology == "dead_layer"
                    else probes.scale_grads_transform()
                )
            if args.timeline:
                stack.enter_context(
                    timeline.timeline_to(args.timeline, run_id="supervised")
                )
        result = run_supervised(
            trainer,
            SupervisorConfig(
                checkpoint_dir=args.ckpt,
                save_every_epochs=args.save_every_epochs,
                progress_file=args.progress_file,
            ),
        )
    if args.out:
        payload = {
            "history": result["history"],
            "resumed_from_step": result["resumed_from_step"],
            "transients_raised": injector.raised if injector else 0,
        }
        if monitor is not None:
            payload["health"] = monitor.health_block()
        Path(args.out).write_text(json.dumps(payload, default=float))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
