"""Deterministic fault injection for resilience tests (DESIGN.md §8).

Every failure mode the recovery layer claims to survive is injectable here,
seeded and replayable:

  * **Process death** — :class:`KillSwitch` delivers a real ``SIGKILL`` to
    the training process at step *k* (uncatchable, mid-step, exactly what a
    preemption looks like from inside); :func:`wait_and_kill` is the
    driver-side variant that watches a supervisor progress file and kills
    the child from outside.
  * **Checkpoint corruption** — :func:`truncate_leaf`, :func:`flip_bytes`,
    :func:`delete_manifest`, :func:`orphan_tmp` damage a published step dir
    the four ways a torn writer / bad disk does; ``CheckpointManager``
    integrity checks must detect all of them.
  * **Transient step failures** — :class:`TransientFaultInjector` raises at
    chosen global steps (first attempt only, or ``persistent=N`` attempts)
    to exercise ``supervisor.retry_step``.
  * **Stragglers / missed heartbeats** — :class:`StragglerInjector` marks
    (worker, round) pairs whose heartbeat should be suppressed, driving
    ``HeartbeatMonitor`` eviction in WASAP and the elastic launch loop;
    it also carries wall-clock delays for the async PS path
    (``AsyncPSConfig.straggler_delay``).
  * **Serving-side faults** — :class:`EngineChaos` composes the two
    injectors above into a ``SparseInferenceEngine.fault_hook``: transient
    raises and straggler stalls keyed on the engine's monotone *call index*
    (prefill/decode/classify invocations), so the serving gateway's retry,
    circuit-breaker and brownout paths (DESIGN.md §9) are exercised by the
    same seeded machinery as the training stack.

:class:`FaultPlan` bundles all of the above; ``FaultPlan.from_seed``
derives a replayable plan from a PRNG seed so a failing resilience run is
reproducible from its seed alone.
"""
from __future__ import annotations

import dataclasses
import json
import os
import signal
import time
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

__all__ = [
    "KillSwitch",
    "TransientFault",
    "TransientFaultInjector",
    "StragglerInjector",
    "EngineChaos",
    "FaultPlan",
    "truncate_leaf",
    "flip_bytes",
    "delete_manifest",
    "orphan_tmp",
    "corrupt",
    "CORRUPTION_MODES",
    "wait_and_kill",
]


# ---------------------------------------------------------------------------
# process death
# ---------------------------------------------------------------------------


class KillSwitch:
    """SIGKILL the current process when the step counter reaches ``at_step``.

    A self-delivered SIGKILL is still uncatchable and instantaneous — the
    process dies mid-step with no atexit/finally cleanup, exactly like an
    external preemption, but at a deterministic step. Trainers call
    ``maybe_kill(gstep)`` through their ``fault_hook``.
    """

    def __init__(self, at_step: Optional[int]):
        self.at_step = at_step

    def maybe_kill(self, step: int) -> None:
        if self.at_step is not None and step >= self.at_step:
            os.kill(os.getpid(), signal.SIGKILL)

    __call__ = maybe_kill


def wait_and_kill(
    proc,
    progress_file: str,
    at_step: int,
    timeout_s: float = 300.0,
    poll_s: float = 0.01,
) -> int:
    """Driver-side kill: poll the supervisor's progress file until the child
    reports ``gstep >= at_step``, then SIGKILL it from outside. Returns the
    step actually observed at kill time (>= ``at_step``); raises on timeout
    or if the child exits first."""
    path = Path(progress_file)
    deadline = time.monotonic() + timeout_s
    while time.monotonic() < deadline:
        if proc.poll() is not None:
            raise RuntimeError(
                f"child exited (rc={proc.returncode}) before step {at_step}"
            )
        if path.exists():
            try:
                seen = int(path.read_text().split()[0])
            except (ValueError, IndexError):
                seen = -1
            if seen >= at_step:
                proc.send_signal(signal.SIGKILL)
                proc.wait()
                return seen
        time.sleep(poll_s)
    raise TimeoutError(f"child never reached step {at_step} in {timeout_s}s")


# ---------------------------------------------------------------------------
# checkpoint corruption
# ---------------------------------------------------------------------------


def _step_dir(ckpt_dir, step: int) -> Path:
    root = Path(ckpt_dir) / f"step_{step:09d}"
    if not root.is_dir():
        raise FileNotFoundError(f"no checkpoint dir {root}")
    return root


def _pick_leaf(root: Path, leaf: Optional[str], rng: np.random.Generator) -> Path:
    if leaf is not None:
        path = root / leaf
        if not path.is_file():
            raise FileNotFoundError(f"no leaf {path}")
        return path
    leaves = sorted(
        p for p in root.rglob("*.npy") if p.is_file()
    ) or sorted(p for p in root.rglob("*") if p.is_file() and p.name != "manifest.json")
    if not leaves:
        raise FileNotFoundError(f"no leaf files under {root}")
    return leaves[int(rng.integers(0, len(leaves)))]


def truncate_leaf(
    ckpt_dir, step: int, leaf: Optional[str] = None, keep_frac: float = 0.5,
    seed: int = 0,
) -> str:
    """Cut a leaf file short — a torn write. Returns the relpath hit."""
    root = _step_dir(ckpt_dir, step)
    path = _pick_leaf(root, leaf, np.random.default_rng(seed))
    size = path.stat().st_size
    with open(path, "r+b") as f:
        f.truncate(max(1, int(size * keep_frac)))
    return str(path.relative_to(root))


def flip_bytes(
    ckpt_dir, step: int, leaf: Optional[str] = None, n_bytes: int = 8,
    seed: int = 0,
) -> str:
    """XOR random bytes inside a leaf's data region — silent bit rot.
    Offsets land past the ~128-byte npy header so the file still *loads*;
    only the checksum can catch it. Returns the relpath hit."""
    rng = np.random.default_rng(seed)
    root = _step_dir(ckpt_dir, step)
    path = _pick_leaf(root, leaf, rng)
    size = path.stat().st_size
    lo = min(128, max(0, size - 1))
    with open(path, "r+b") as f:
        for off in rng.integers(lo, size, n_bytes):
            f.seek(int(off))
            b = f.read(1)
            f.seek(int(off))
            f.write(bytes([b[0] ^ 0xFF]))
    return str(path.relative_to(root))


def delete_manifest(ckpt_dir, step: int) -> str:
    """Remove manifest.json — the publish record is gone."""
    root = _step_dir(ckpt_dir, step)
    (root / "manifest.json").unlink()
    return "manifest.json"


def orphan_tmp(ckpt_dir, step: int) -> str:
    """Leave a half-written tmp dir behind, as a writer killed mid-save
    does. Returns the tmp dir name (manager init must sweep it)."""
    tmp = Path(ckpt_dir) / f".tmp_step_{step:09d}"
    (tmp / "arrays").mkdir(parents=True, exist_ok=True)
    (tmp / "arrays" / "partial.npy").write_bytes(b"\x93NUMPY... torn")
    return tmp.name


CORRUPTION_MODES = {
    "truncate_leaf": truncate_leaf,
    "flip_bytes": flip_bytes,
    "delete_manifest": delete_manifest,
    "orphan_tmp": orphan_tmp,
}


def corrupt(mode: str, ckpt_dir, step: int, **kw) -> str:
    """Apply one named corruption mode; returns what was damaged."""
    return CORRUPTION_MODES[mode](ckpt_dir, step, **kw)


# ---------------------------------------------------------------------------
# transient step failures
# ---------------------------------------------------------------------------


class TransientFault(RuntimeError):
    """The injected transient failure (preemption blip / ICI flap)."""


class TransientFaultInjector:
    """Raise :class:`TransientFault` at chosen global steps.

    ``persistent`` controls how many consecutive attempts at the same step
    fail before it succeeds (1 = fails once, recovered by the first retry).
    ``raised`` counts injections so tests can assert the path was exercised.
    """

    def __init__(self, fail_steps: Sequence[int], persistent: int = 1):
        self.fail_steps: Set[int] = set(int(s) for s in fail_steps)
        self.persistent = persistent
        self.attempts: Dict[int, int] = {}
        self.raised = 0

    def __call__(self, step: int) -> None:
        if step not in self.fail_steps:
            return
        seen = self.attempts.get(step, 0)
        self.attempts[step] = seen + 1
        if seen < self.persistent:
            self.raised += 1
            raise TransientFault(f"injected transient failure at step {step}")


# ---------------------------------------------------------------------------
# stragglers / missed heartbeats
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class StragglerInjector:
    """Declarative straggler schedule.

    ``suppress`` maps worker id -> rounds/epochs whose heartbeat is
    suppressed (None = all rounds from the first listed onward is expressed
    by an explicit range upstream); ``delay_s`` is a wall-clock delay for
    paths that really sleep (the async PS worker 0 injection).
    """

    suppress: Dict[str, Set[int]] = dataclasses.field(default_factory=dict)
    delay_s: float = 0.0

    def beats(self, worker_id: str, round_index: int) -> bool:
        """Does this worker's heartbeat arrive this round?"""
        return round_index not in self.suppress.get(worker_id, ())


# ---------------------------------------------------------------------------
# serving-side engine faults
# ---------------------------------------------------------------------------


class EngineChaos:
    """A ``SparseInferenceEngine.fault_hook`` built from the two injectors.

    The engine calls ``hook(op, call_index)`` at the top of every served
    entry point (prefill/decode/classify), before any cache mutation.
    ``transient`` is a :class:`TransientFaultInjector` keyed on the call
    index (its ``persistent`` knob decides whether one gateway retry
    recovers the call or the failure sticks long enough to trip the
    breaker); ``straggler`` reuses :class:`StragglerInjector` with the op
    name as the worker id — a suppressed "beat" stalls the call by
    ``delay_s`` (a slow device, not a dead one). Both schedules live in
    call-index space, so a chaos scenario is deterministic regardless of
    wall-clock jitter.
    """

    def __init__(
        self,
        transient: Optional[TransientFaultInjector] = None,
        straggler: Optional[StragglerInjector] = None,
        sleep=time.sleep,
    ):
        self.transient = transient
        self.straggler = straggler
        self.sleep = sleep
        self.calls = 0

    def __call__(self, op: str, call_index: int) -> None:
        self.calls += 1
        if self.straggler is not None and not self.straggler.beats(
            op, call_index
        ):
            self.sleep(self.straggler.delay_s)
        if self.transient is not None:
            self.transient(call_index)

    @property
    def raised(self) -> int:
        return self.transient.raised if self.transient is not None else 0


# ---------------------------------------------------------------------------
# the plan
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class FaultPlan:
    """One seeded, serializable bundle of scheduled faults.

    Fields are all optional — an empty plan injects nothing, so the same
    harness drives both the fault run and its clean control.
    """

    seed: int = 0
    kill_at_step: Optional[int] = None
    transient_steps: Tuple[int, ...] = ()
    transient_persistent: int = 1
    corruptions: Tuple[Tuple[str, int], ...] = ()  # (mode, ckpt step)
    straggler_suppress: Dict[str, Tuple[int, ...]] = dataclasses.field(
        default_factory=dict
    )
    straggler_delay_s: float = 0.0

    @classmethod
    def from_seed(
        cls,
        seed: int,
        *,
        total_steps: int,
        ckpt_steps: Sequence[int] = (),
        n_kills: int = 1,
        n_transients: int = 1,
        corruption_modes: Sequence[str] = (),
    ) -> "FaultPlan":
        """Derive a replayable plan: kill point, transient steps and
        corruption targets all drawn from ``seed``."""
        rng = np.random.default_rng(seed)
        kill = (
            int(rng.integers(1, max(2, total_steps)))
            if n_kills else None
        )
        transients = tuple(
            sorted(
                int(s)
                for s in rng.choice(
                    max(1, total_steps), size=min(n_transients, total_steps),
                    replace=False,
                )
            )
        )
        corr = []
        ckpt_steps = list(ckpt_steps)
        for mode in corruption_modes:
            if mode not in CORRUPTION_MODES:
                raise ValueError(f"unknown corruption mode {mode!r}")
            target = (
                int(ckpt_steps[int(rng.integers(0, len(ckpt_steps)))])
                if ckpt_steps else 0
            )
            corr.append((mode, target))
        return cls(
            seed=seed,
            kill_at_step=kill,
            transient_steps=transients,
            corruptions=tuple(corr),
        )

    # -- runtime views -------------------------------------------------------

    def kill_switch(self) -> KillSwitch:
        return KillSwitch(self.kill_at_step)

    def transient_injector(self) -> TransientFaultInjector:
        return TransientFaultInjector(
            self.transient_steps, persistent=self.transient_persistent
        )

    def straggler_injector(self) -> StragglerInjector:
        return StragglerInjector(
            suppress={w: set(r) for w, r in self.straggler_suppress.items()},
            delay_s=self.straggler_delay_s,
        )

    def apply_corruptions(self, ckpt_dir) -> List[str]:
        """Damage the checkpoint dir per plan; returns what was hit."""
        return [
            f"{mode}:{corrupt(mode, ckpt_dir, step, **({'seed': self.seed} if mode in ('truncate_leaf', 'flip_bytes') else {}))}"
            for mode, step in self.corruptions
        ]

    def to_json(self) -> str:
        d = dataclasses.asdict(self)
        d["straggler_suppress"] = {
            w: list(r) for w, r in self.straggler_suppress.items()
        }
        return json.dumps(d)

    @classmethod
    def from_json(cls, s: str) -> "FaultPlan":
        d = json.loads(s)
        d["transient_steps"] = tuple(d.get("transient_steps", ()))
        d["corruptions"] = tuple(
            (m, int(st)) for m, st in d.get("corruptions", ())
        )
        d["straggler_suppress"] = {
            w: tuple(r) for w, r in d.get("straggler_suppress", {}).items()
        }
        return cls(**d)
