"""Shard-wise SET evolution for the out-of-core substrate (DESIGN.md §7).

The paper's prune criterion is *global* per layer — the zeta-tail of the
smallest positive and largest negative weights — but the whole-layer
``evolve_element`` materializes and argsorts the full ``(nnz,)`` value
array, which is exactly what an out-of-core layer cannot afford. Here the
global thresholds come from a **streamed two-pass quantile sketch**:

  1. *count pass* — stream shards, count positives/negatives/zeros and the
     nonzero-|v| range;
  2. *histogram pass* — stream shards again, per-sign |v| histograms over
     that range; invert the CDF to the bin holding the k-th smallest;
  3. *boundary resolution* — stream only the boundary bin's values (about
     nnz/bins of them, the sole data-dependent allocation) and select the
     exact k-th order statistic inside it, with deterministic canonical-
     stream-order tie handling.

The resulting threshold is the *exact* per-sign quantile — the sketch
"tolerance" collapses to tie-ordering — so the shard-wise pass prunes
exactly ``int(zeta * n_pos) + int(zeta * n_neg) + n_zero`` connections, the
same count as the whole-layer oracle.

Regrowth is drawn **per shard**: shard s owns the canonical-key interval
``[edges[s], edges[s+1])`` (``core.topology.element_shard_key_intervals``),
so sampling vacancies inside its own interval needs only the shard's own
keys for the occupancy check, preserves global uniqueness and cross-shard
canonical order, and keeps every shard at constant capacity (regrow count
== local prune count). The distributional difference vs whole-layer uniform
regrowth: new connections land proportionally to where pruning happened
rather than uniformly over all vacancies — the low-magnitude tail is close
to uniform over shards in practice (asserted distributionally in tests).

After the values move, the row-sorted dual order is rebuilt by an external
k-way merge of the shards' locally row-sorted runs (spilled to disk-backed
scratch in the memmapped regime, block-buffered readers) — no whole-layer
argsort, O(shards * block) merge memory.
"""
from __future__ import annotations

import dataclasses
import heapq
from pathlib import Path
from typing import List, Optional, Tuple

import numpy as np

from repro.core.sparsity import _init_numpy
from repro.core.topology import (
    element_shard_bounds,
    element_shard_key_intervals,
)

__all__ = [
    "SignThreshold",
    "streamed_sign_thresholds",
    "evolve_layer_streamed",
    "evolve_model_streamed",
]


@dataclasses.dataclass(frozen=True)
class SignThreshold:
    """Exact prune rule for one sign class: prune every |v| in a bin below
    ``boundary_bin``; inside it, every |v| below ``cutoff`` plus the first
    ``ties`` entries equal to it (canonical stream order)."""

    k: int               # target prune count (int(zeta * n_sign))
    boundary_bin: int
    cutoff: float        # exact k-th smallest |v| of this sign
    ties: int            # cutoff-equal entries to prune, in stream order


def _bin_of(absv: np.ndarray, lo: float, width: float, bins: int) -> np.ndarray:
    idx = np.floor((absv - lo) / width).astype(np.int64)
    return np.clip(idx, 0, bins - 1)


def streamed_sign_thresholds(
    values, capacity: int, zeta: float, *, bins: int = 8192
) -> Tuple[Optional[SignThreshold], Optional[SignThreshold], dict]:
    """Two-pass (plus boundary-bin) streamed quantile sketch over a host
    value leaf. Returns (pos, neg) thresholds (None when that sign prunes
    nothing) and the pass statistics."""
    nnz = values.shape[0]
    bounds = element_shard_bounds(nnz, capacity)

    # pass 1: sign counts + nonzero |v| range
    n_pos = n_neg = n_zero = 0
    lo, hi = np.inf, -np.inf
    for a, b in bounds:
        v = np.asarray(values[a:b], np.float32)
        n_pos += int((v > 0).sum())
        n_neg += int((v < 0).sum())
        n_zero += int((v == 0).sum())
        nz = np.abs(v[v != 0])
        if nz.size:
            lo = min(lo, float(nz.min()))
            hi = max(hi, float(nz.max()))
    stats = {"n_pos": n_pos, "n_neg": n_neg, "n_zero": n_zero}
    k_pos = int(zeta * n_pos)  # same float64 arithmetic as evolve_element
    k_neg = int(zeta * n_neg)
    if k_pos == 0 and k_neg == 0:
        return None, None, stats
    width = max((hi - lo) / bins, np.finfo(np.float32).tiny)

    # pass 2: per-sign histograms
    hist = {s: np.zeros(bins, np.int64) for s in (+1, -1)}
    for a, b in bounds:
        v = np.asarray(values[a:b], np.float32)
        for s in (+1, -1):
            sel = v > 0 if s > 0 else v < 0
            if sel.any():
                idx = _bin_of(np.abs(v[sel]), lo, width, bins)
                np.add.at(hist[s], idx, 1)

    # pass 3: exact selection inside the boundary bin
    def resolve(sign: int, k: int) -> Optional[SignThreshold]:
        if k <= 0:
            return None
        cum = np.cumsum(hist[sign])
        b_idx = int(np.searchsorted(cum, k))
        below = int(cum[b_idx - 1]) if b_idx > 0 else 0
        need = k - below
        bucket: List[np.ndarray] = []
        for a, b in bounds:
            v = np.asarray(values[a:b], np.float32)
            sel = v > 0 if sign > 0 else v < 0
            av = np.abs(v[sel])
            inb = av[_bin_of(av, lo, width, bins) == b_idx]
            if inb.size:
                bucket.append(inb)
        boundary = (
            np.sort(np.concatenate(bucket)) if bucket
            else np.empty(0, np.float32)
        )
        assert boundary.size >= need, (boundary.size, need)
        cutoff = float(boundary[need - 1])
        ties = need - int((boundary < cutoff).sum())
        return SignThreshold(k=k, boundary_bin=b_idx, cutoff=cutoff, ties=ties)

    stats.update(lo=lo, hi=hi, width=width, bins=bins)
    return resolve(+1, k_pos), resolve(-1, k_neg), stats


def _prune_mask(
    v: np.ndarray,
    thr: Optional[SignThreshold],
    sign: int,
    lo: float,
    width: float,
    bins: int,
    ties_left: List[int],
) -> np.ndarray:
    """This shard's prune flags for one sign class; ``ties_left`` is the
    mutable cross-shard tie budget (canonical stream order)."""
    if thr is None:
        return np.zeros(v.shape, bool)
    sel = v > 0 if sign > 0 else v < 0
    av = np.abs(v).astype(np.float32)
    b = _bin_of(av, lo, width, bins)
    mask = sel & (b < thr.boundary_bin)
    in_b = sel & (b == thr.boundary_bin)
    mask |= in_b & (av < thr.cutoff)
    if ties_left[0] > 0:
        tie = in_b & (av == thr.cutoff)
        tie_idx = np.flatnonzero(tie)[: ties_left[0]]
        ties_left[0] -= tie_idx.size
        m2 = np.zeros(v.shape, bool)
        m2[tie_idx] = True
        mask |= m2
    return mask


def evolve_layer_streamed(
    st,
    zeta: float,
    rng: np.random.Generator,
    *,
    capacity: int,
    init_scheme: str = "he_uniform",
    bins: int = 8192,
) -> dict:
    """One layer's shard-wise prune/regrow cycle on an ``XLLayerState``.

    Streams the layer three+1 times (sketch passes + the mutation pass);
    every allocation is O(capacity) except the boundary-bin collection
    (~nnz/bins). Returns the evolution stats (prune counts, thresholds).
    """
    nnz = st.nnz
    bounds = element_shard_bounds(nnz, capacity)
    thr_pos, thr_neg, stats = streamed_sign_thresholds(
        st.values, capacity, zeta, bins=bins
    )
    edges = element_shard_key_intervals(
        st.rows, st.cols, st.in_dim, st.out_dim, capacity
    )
    ties_pos, ties_neg = (
        [thr_pos.ties if thr_pos else 0],
        [thr_neg.ties if thr_neg else 0],
    )
    lo_v = stats.get("lo", 0.0)
    width = stats.get("width", 1.0)
    n_pruned = n_fallback = 0
    for s, (a, b) in enumerate(bounds):
        v = np.asarray(st.values[a:b], np.float32)
        rows = np.asarray(st.rows[a:b])
        cols = np.asarray(st.cols[a:b])
        vel = np.asarray(st.velocity[a:b], np.float32)
        drop = (v == 0)
        drop |= _prune_mask(v, thr_pos, +1, lo_v, width, bins, ties_pos)
        drop |= _prune_mask(v, thr_neg, -1, lo_v, width, bins, ties_neg)
        k_s = int(drop.sum())
        n_pruned += k_s
        if k_s == 0:
            continue
        keys = cols.astype(np.int64) * st.in_dim + rows.astype(np.int64)
        kept_keys = np.sort(keys[~drop])
        interval = (int(edges[s]), int(edges[s + 1]))
        new_keys, fallback = _sample_interval_vacancies(
            rng, interval, kept_keys, k_s, keys[drop]
        )
        n_fallback += fallback
        new_vals = _init_numpy(
            rng, (k_s,), fan_in_dense=st.in_dim, scheme=init_scheme
        )
        # rebuild the shard: survivors + regrown, re-sorted by canonical key
        out_keys = np.concatenate([keys[~drop], new_keys])
        out_vals = np.concatenate([v[~drop], new_vals])
        out_vel = np.concatenate([vel[~drop], np.zeros(k_s, np.float32)])
        order = np.argsort(out_keys, kind="stable")
        out_keys = out_keys[order]
        st.cols[a:b] = (out_keys // st.in_dim).astype(np.int32)
        st.rows[a:b] = (out_keys % st.in_dim).astype(np.int32)
        st.values[a:b] = out_vals[order]
        st.velocity[a:b] = out_vel[order]
    _rebuild_row_order_streamed(st, capacity)
    stats.update(
        n_pruned=n_pruned,
        n_grown=n_pruned,
        n_fallback=n_fallback,
        cutoff_pos=thr_pos.cutoff if thr_pos else None,
        cutoff_neg=thr_neg.cutoff if thr_neg else None,
    )
    return stats


def _sample_interval_vacancies(
    rng: np.random.Generator,
    interval: Tuple[int, int],
    kept_keys: np.ndarray,
    k: int,
    dropped_keys: np.ndarray,
) -> Tuple[np.ndarray, int]:
    """``k`` distinct canonical keys inside ``interval`` avoiding
    ``kept_keys``. When the interval is too saturated to yield enough fresh
    vacancies (bounded rejection rounds), the remainder reuses the dropped
    slots' own keys — position kept, value re-initialized — the same
    vanishing-probability fallback the device regrowth uses."""
    lo, hi = interval
    vacant = (hi - lo) - kept_keys.size
    picked: set = set()
    rounds = 0
    while len(picked) < min(k, vacant) and rounds < 16:
        cand = rng.integers(lo, hi, size=2 * (k - len(picked)))
        pos = np.searchsorted(kept_keys, cand)
        pos = np.clip(pos, 0, max(0, kept_keys.size - 1))
        occ = (
            kept_keys[pos] == cand if kept_keys.size else
            np.zeros(cand.shape, bool)
        )
        for c in cand[~occ]:
            ci = int(c)
            if ci not in picked:
                picked.add(ci)
                if len(picked) == k:
                    break
        rounds += 1
    new = np.fromiter(picked, np.int64, len(picked))
    n_fallback = k - new.size
    if n_fallback:
        reuse = np.setdiff1d(dropped_keys, new)[:n_fallback]
        assert reuse.size == n_fallback
        new = np.concatenate([new, reuse.astype(np.int64)])
    return new, n_fallback


def _scratch_like(ref: np.ndarray, n: int, name: str) -> np.ndarray:
    """int64 scratch of length ``n``: spilled to a sibling memmap when the
    layer's leaves are themselves memmapped (the out-of-core regime — the
    scratch must not claim O(nnz) RSS either), plain memory otherwise."""
    if isinstance(ref, np.memmap) and getattr(ref, "filename", None):
        path = Path(ref.filename).with_suffix(f".{name}.tmp")
        return np.memmap(path, dtype=np.int64, mode="w+", shape=(n,))
    return np.empty(n, np.int64)


def _release_scratch(arr: np.ndarray) -> None:
    if isinstance(arr, np.memmap) and getattr(arr, "filename", None):
        path = Path(arr.filename)
        del arr
        path.unlink(missing_ok=True)


def _rebuild_row_order_streamed(
    st, capacity: int, block: int = 8192, write_chunk: int = 65536
):
    """Rebuild ``perm_r`` as an external k-way merge of the shards' locally
    row-sorted runs. Two phases, both with bounded working set:

    1. each shard's connections are sorted by (row, col) and the sorted
       (key, canonical-index) run is spilled to scratch — one O(capacity)
       sort at a time, scratch on disk whenever the layer's own leaves are
       memmapped;
    2. ``heapq.merge`` over *block-buffered* readers of those runs — every
       live reader holds one ``block``-sized window, so the merge's host
       memory is O(shards * block), never O(nnz) — writing the merged
       permutation to the leaf in fixed-size chunks.
    """
    bounds = element_shard_bounds(st.nnz, capacity)
    run_keys = _scratch_like(st.perm_r, st.nnz, "rkeys")
    run_idx = _scratch_like(st.perm_r, st.nnz, "ridx")
    for a, b in bounds:
        rows = np.asarray(st.rows[a:b], np.int64)
        cols = np.asarray(st.cols[a:b], np.int64)
        keys = rows * st.out_dim + cols
        order = np.argsort(keys, kind="stable")
        run_keys[a:b] = keys[order]
        run_idx[a:b] = order + a

    def reader(a, b):
        for lo in range(a, b, block):
            hi = min(lo + block, b)
            k = np.asarray(run_keys[lo:hi]).tolist()
            i = np.asarray(run_idx[lo:hi]).tolist()
            yield from zip(k, i)

    pos = 0
    buf = np.empty(write_chunk, np.int64)
    fill = 0
    for _, canonical in heapq.merge(*(reader(a, b) for a, b in bounds)):
        buf[fill] = canonical
        fill += 1
        if fill == write_chunk:
            st.perm_r[pos : pos + fill] = buf
            pos += fill
            fill = 0
    if fill:
        st.perm_r[pos : pos + fill] = buf[:fill]
    _release_scratch(run_keys)
    _release_scratch(run_idx)


def evolve_model_streamed(
    state, zeta: float, rng: np.random.Generator, *, bins: int = 8192
) -> List[dict]:
    """Shard-wise evolution over every layer of an ``XLModelState``; bumps
    ``topo_version`` so the executor drops its device-cached index shards."""
    out = []
    for st in state.layers:
        out.append(
            evolve_layer_streamed(
                st, zeta, rng,
                capacity=state.plan.shard_capacity,
                init_scheme=state.init, bins=bins,
            )
        )
    state.topo_version += 1
    return out
