"""repro.xl — out-of-core extreme-scale training substrate (DESIGN.md §7).

Trains element-sparse MLPs whose live parameters (values + dual-order COO
topology + momentum) exceed device memory: a memory-budget **planner**
solves for a static shard capacity/chunk width/leaf placement, the
**stream** executor runs forward/backward as a double-buffered
connection-shard stream over two jitted per-shard programs
(``kernels.ops.xl_shard_acc`` / ``xl_shard_dw``; zero recompiles across
shards, layers and epochs), and **evolve** runs the SET prune/regrow cycle
shard-wise with a streamed quantile sketch so no whole-layer ``(nnz,)``
array is ever materialized. The plan artifact is shared by the trainer
(``train.trainer.XLTrainer``), the streamed checkpoint path
(``CheckpointManager.save_streamed``) and the Table-4 benchmarks.
"""
from repro.xl.evolve import (
    evolve_layer_streamed,
    evolve_model_streamed,
    streamed_sign_thresholds,
)
from repro.xl.planner import (
    PlannerError,
    XLLayerPlan,
    XLPlan,
    estimate_in_core_bytes,
    plan_memory_budget,
)
from repro.xl.stream import (
    StreamExecutor,
    XLLayerState,
    XLModelState,
    compile_counts,
)

__all__ = [
    "PlannerError",
    "XLLayerPlan",
    "XLPlan",
    "plan_memory_budget",
    "estimate_in_core_bytes",
    "StreamExecutor",
    "XLLayerState",
    "XLModelState",
    "compile_counts",
    "evolve_layer_streamed",
    "evolve_model_streamed",
    "streamed_sign_thresholds",
]
