"""Memory-budget planner for the out-of-core XL substrate (DESIGN.md §7).

``plan_memory_budget`` takes a device-bytes budget and a model spec and
solves for the three knobs the streamed executor needs:

* **shard capacity** — the static per-shard slot count. One capacity serves
  every layer (ragged tails are padded with segment sentinels), so the two
  per-shard device programs (``kernels.ops.xl_shard_acc`` / ``xl_shard_dw``)
  compile exactly once for the whole model. Capacity is forced to a multiple
  of the chunk width: shard boundaries then land on chunk boundaries and the
  streamed accumulation reproduces the in-core chunk partition (and with it
  the f32 addition order) exactly.
* **chunk width** — the ``spmm_chunk_for``-compatible width of the chunked
  segment-sum passes. Starts at the batch-aware default and halves under
  tight budgets (the chunk slab is device memory too).
* **leaf placement** — biases and the d_max-padded activation/gradient
  buffers are always device-resident; weight values and optimizer state are
  always host-pinned (memmap-backed above ``memmap_threshold_bytes``) and
  streamed; topology index shards are device-cached ("resident") per layer
  when the leftover budget allows — indices are immutable between evolution
  events, so caching them halves the steady-state transfer volume without
  any coherence risk (the executor invalidates the cache on evolution).

The result is a plan *artifact* (JSON round-trip) consumed by the XL
trainer, the streamed checkpoint writer and the benchmarks — all three see
the same arithmetic, and the CI smoke asserts ``peak_device_bytes`` never
exceeds the budget it was solved for.
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Optional, Sequence, Tuple

from repro.core.sparsity import spmm_chunk_for
from repro.core.topology import element_shard_bounds

__all__ = [
    "PlannerError",
    "XLLayerPlan",
    "XLPlan",
    "plan_memory_budget",
    "estimate_in_core_bytes",
]

# Device bytes per shard *slot* while streaming: the value (f32) plus the two
# int32 index arrays of whichever order is in flight, double-buffered (shard
# k computes while shard k+1 transfers), plus the per-shard dW output slot.
_SLOT_BYTES_STREAMED = 2 * (4 + 8) + 4
# Device bytes per *connection* for a layer whose topology indices are cached
# device-resident: both orders' index arrays (rows/cols + rows_r/cols_r).
_TOPO_RESIDENT_BYTES = 16
# The chunked passes' peak temp: the (chunk, B) contribution slab plus the
# staged segment-sum output of the same size.
_CHUNK_SLABS = 2
# Activation-shaped (d_max, B) device buffers alive at the backward peak:
# x input, one pre-activation z per layer, the accumulator, the upstream
# gradient, the dX accumulator and the recomputed h_prev (+1 slack for the
# transfer of the next batch).
_N_BUFFERS_BASE = 5


class PlannerError(ValueError):
    """The budget cannot hold even the minimal streamed configuration; the
    message itemizes the fixed components so the caller can see what to cut
    (batch, width, chunk floor)."""


@dataclasses.dataclass(frozen=True)
class XLLayerPlan:
    index: int
    in_dim: int
    out_dim: int
    nnz: int
    n_shards: int
    topo_resident: bool  # index shards cached on device between evolutions


@dataclasses.dataclass(frozen=True)
class XLPlan:
    budget_bytes: int
    batch: int
    d_max: int
    shard_capacity: int
    chunk: int
    layers: Tuple[XLLayerPlan, ...]
    peak_device_bytes: int
    memmap_threshold_bytes: int
    dtype_bytes: int = 4

    @property
    def n_layers(self) -> int:
        return len(self.layers)

    @property
    def n_shards_total(self) -> int:
        return sum(l.n_shards for l in self.layers)

    @property
    def buffer_bytes(self) -> int:
        return self.d_max * self.batch * self.dtype_bytes

    def to_json(self) -> str:
        d = dataclasses.asdict(self)
        d["layers"] = [dataclasses.asdict(l) for l in self.layers]
        return json.dumps(d, indent=2)

    @classmethod
    def from_json(cls, text: str) -> "XLPlan":
        d = json.loads(text)
        d["layers"] = tuple(XLLayerPlan(**l) for l in d["layers"])
        return cls(**d)

    def save(self, path) -> None:
        Path(path).write_text(self.to_json() + "\n")

    @classmethod
    def load(cls, path) -> "XLPlan":
        return cls.from_json(Path(path).read_text())


def _fixed_bytes(
    layer_dims: Sequence[int], batch: int, dtype_bytes: int
) -> int:
    """Device bytes that do not scale with shard capacity: the activation/
    gradient buffers and the (padded) biases + bias gradients."""
    d_max = max(layer_dims)
    n_layers = len(layer_dims) - 1
    buffers = (_N_BUFFERS_BASE + n_layers) * d_max * batch * dtype_bytes
    biases = 3 * sum(layer_dims[1:]) * dtype_bytes
    return buffers + biases


def plan_memory_budget(
    layer_dims: Sequence[int],
    nnz_per_layer: Sequence[int],
    batch: int,
    budget_bytes: int,
    *,
    dtype_bytes: int = 4,
    chunk: Optional[int] = None,
    min_chunk: int = 64,
    memmap_threshold_bytes: int = 1 << 27,
) -> XLPlan:
    """Solve (shard capacity, chunk, leaf placement) for a device budget.

    Raises :class:`PlannerError` when infeasible — the fixed buffers alone
    exceed the budget, or no (capacity, chunk) pair fits with capacity >=
    chunk >= ``min_chunk``.
    """
    if len(nnz_per_layer) != len(layer_dims) - 1:
        raise ValueError("nnz_per_layer must have len(layer_dims) - 1 entries")
    if any(n <= 0 for n in nnz_per_layer):
        raise ValueError(f"every layer needs nnz >= 1, got {nnz_per_layer}")
    d_max = max(layer_dims)
    max_nnz = max(nnz_per_layer)
    fixed = _fixed_bytes(layer_dims, batch, dtype_bytes)
    if fixed >= budget_bytes:
        raise PlannerError(
            f"infeasible budget {budget_bytes}: the device-resident floor "
            f"alone needs {fixed} bytes "
            f"({_N_BUFFERS_BASE + len(layer_dims) - 1} activation buffers of "
            f"{d_max}x{batch}x{dtype_bytes}B + biases); shrink the batch or "
            f"the widest layer"
        )

    # chunk descent: the slab is device memory, so a tight budget trades
    # chunk width (scan steps) for headroom before giving up
    c0 = chunk if chunk is not None else spmm_chunk_for(batch, max_nnz)
    c0 = max(min_chunk, min(int(c0), max_nnz))
    chosen = None
    w = c0
    while w >= min_chunk:
        slab = _CHUNK_SLABS * w * batch * dtype_bytes
        avail = budget_bytes - fixed - slab
        cap = (avail // _SLOT_BYTES_STREAMED // w) * w  # multiple of chunk
        # capacity beyond the largest layer (rounded up to a whole number of
        # chunks) buys nothing but padding
        cap_ceil = -(-max_nnz // w) * w
        cap = min(cap, cap_ceil)
        if cap >= w:
            chosen = (cap, w)
            break
        w //= 2
    if chosen is None:
        raise PlannerError(
            f"infeasible budget {budget_bytes}: fixed floor {fixed}B leaves "
            f"no room for one {min_chunk}-slot shard "
            f"(+{_CHUNK_SLABS * min_chunk * batch * dtype_bytes}B chunk slab, "
            f"{_SLOT_BYTES_STREAMED}B/slot double-buffered)"
        )
    capacity, chunk_w = chosen
    peak = (
        fixed
        + _CHUNK_SLABS * chunk_w * batch * dtype_bytes
        + capacity * _SLOT_BYTES_STREAMED
    )

    # leftover budget -> device-cache topology indices, smallest layers
    # first (most shards avoided per byte; indices are immutable between
    # evolution events so this is pure transfer savings)
    leftover = budget_bytes - peak
    order = sorted(range(len(nnz_per_layer)), key=lambda l: nnz_per_layer[l])
    resident = set()
    for l in order:
        n_shards = len(element_shard_bounds(nnz_per_layer[l], capacity))
        topo_bytes = n_shards * capacity * _TOPO_RESIDENT_BYTES
        if topo_bytes <= leftover:
            resident.add(l)
            leftover -= topo_bytes
            peak += topo_bytes

    layers = tuple(
        XLLayerPlan(
            index=l,
            in_dim=int(layer_dims[l]),
            out_dim=int(layer_dims[l + 1]),
            nnz=int(nnz_per_layer[l]),
            n_shards=len(element_shard_bounds(nnz_per_layer[l], capacity)),
            topo_resident=l in resident,
        )
        for l in range(len(nnz_per_layer))
    )
    assert peak <= budget_bytes, (peak, budget_bytes)
    return XLPlan(
        budget_bytes=int(budget_bytes),
        batch=int(batch),
        d_max=int(d_max),
        shard_capacity=int(capacity),
        chunk=int(chunk_w),
        layers=layers,
        peak_device_bytes=int(peak),
        memmap_threshold_bytes=int(memmap_threshold_bytes),
        dtype_bytes=int(dtype_bytes),
    )


def estimate_in_core_bytes(
    layer_dims: Sequence[int],
    nnz_per_layer: Sequence[int],
    batch: int,
    *,
    dtype_bytes: int = 4,
) -> int:
    """Device footprint of the in-core fused trainer for the same model:
    values + velocity (f32) and the dual-order ``ElemTopoArrays`` (7 int32
    arrays) per layer, biases + velocity, and the live activation set of one
    value_and_grad step (~2 tensors per layer boundary). The benchmark's
    "equal budget" comparisons (table4/xl_*) hand the planner a budget below
    this number to force genuine streaming."""
    total = 0
    for l, nnz in enumerate(nnz_per_layer):
        total += nnz * (2 * dtype_bytes + 7 * 4)
    total += 2 * sum(layer_dims[1:]) * dtype_bytes
    total += 2 * sum(d * batch * dtype_bytes for d in layer_dims)
    return total
