"""Shard-streamed out-of-core forward/backward (DESIGN.md §7).

The substrate trains element-sparse MLPs whose live parameters (values +
dual-order topology + momentum) never fit on the device at once:

* **Host-pinned leaves** — per layer, the canonical COO arrays (rows, cols),
  the row-order permutation ``perm_r``, values and velocity live in host
  numpy, memmap-backed above the plan's size threshold. The device only
  ever holds one fixed-capacity *connection shard* of them (plus its
  double-buffered successor).
* **Streamed matmuls** — forward and dX are both runs of the ONE jitted
  per-shard program ``kernels.ops.xl_shard_acc`` over a d_max-padded
  ``(d_max, batch)`` transposed activation buffer: forward streams the
  canonical order (gather rows / segment cols), dX streams the row-sorted
  dual order (gather cols_r / segment rows_r, values host-gathered through
  ``perm_r``). Shard capacity is a multiple of the chunk width, so the
  streamed accumulation's chunk partition — and with it the f32 addition
  order — is identical to the in-core chunked segment-sum.
* **Double buffering** — shard k+1's host->device transfer is issued before
  shard k's compute is awaited (JAX dispatch is asynchronous), so transfer
  and compute overlap.
* **Host optimizer** — dW is computed per shard (``xl_shard_dw``), pulled to
  the host and applied immediately as a momentum-SGD update on the shard's
  value/velocity slice; no whole-layer gradient is ever materialized on
  either side of the PCIe bus.

Zero recompiles by construction: every device program here has fully static
shapes derived from the plan (d_max, batch, capacity, chunk), so streaming
more shards, layers or epochs never grows any jit cache —
``compile_counts()`` exposes the caches and the tests pin them.
"""
from __future__ import annotations

import dataclasses
import functools
import tempfile
from pathlib import Path
from typing import Iterator, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.topology import (
    check_element_shards,
    element_row_order,
    element_shard_bounds,
    pad_shard,
)
from repro.kernels.ops import (
    make_xl_shard_acc,
    make_xl_shard_dw,
    xl_shard_acc,
    xl_shard_dw,
)
from repro.runtime import donation
from repro.xl.planner import XLPlan
from repro import obs
from repro.obs import probes

__all__ = [
    "XLLayerState",
    "XLModelState",
    "StreamExecutor",
    "host_leaf",
    "compile_counts",
]


# ---------------------------------------------------------------------------
# host-pinned leaves
# ---------------------------------------------------------------------------


def host_leaf(
    arr: np.ndarray,
    *,
    threshold_bytes: int,
    spool_dir: Optional[Path],
    name: str,
) -> np.ndarray:
    """Pin an array host-side: a plain ndarray below the threshold, an
    anonymous-file memmap above it (so leaves larger than comfortable RSS
    spill to the page cache; the OS pages shards in as they stream)."""
    arr = np.ascontiguousarray(arr)
    if spool_dir is None or arr.nbytes < threshold_bytes:
        # device arrays surface as read-only numpy views; the optimizer
        # updates leaves in place, so own a writable copy
        return arr.copy() if not arr.flags.writeable else arr
    spool_dir.mkdir(parents=True, exist_ok=True)
    path = spool_dir / f"{name}.mm"
    mm = np.memmap(path, dtype=arr.dtype, mode="w+", shape=arr.shape)
    mm[...] = arr
    return mm


@dataclasses.dataclass
class XLLayerState:
    """One layer's host-pinned state. Canonical (col, row) order throughout;
    ``perm_r`` maps row-order slot -> canonical slot (int64)."""

    in_dim: int
    out_dim: int
    rows: np.ndarray      # int32 (nnz,)
    cols: np.ndarray      # int32 (nnz,)
    perm_r: np.ndarray    # int64 (nnz,)
    values: np.ndarray    # f32 (nnz,)
    velocity: np.ndarray  # f32 (nnz,)
    bias: np.ndarray      # f32 (out_dim,)
    bias_vel: np.ndarray  # f32 (out_dim,)

    @property
    def nnz(self) -> int:
        return int(self.rows.shape[0])


@dataclasses.dataclass
class XLModelState:
    """Whole-model host state + the plan that shaped it. ``topo_version``
    bumps on every topology mutation (SET evolution) so the executor can
    invalidate any device-cached index shards."""

    layer_dims: Tuple[int, ...]
    activation: str
    alpha: float
    init: str
    layers: List[XLLayerState]
    plan: XLPlan
    spool_dir: Optional[Path] = None
    topo_version: int = 0

    @property
    def n_layers(self) -> int:
        return len(self.layers)

    @classmethod
    def from_model(
        cls, model, plan: XLPlan, spool_dir: Optional[str] = None
    ) -> "XLModelState":
        """Build host state from an in-core ``SparseMLP`` (element impl) —
        the shared entry for tests/benchmarks, so the XL run starts from the
        exact same draw as its in-core oracle. Velocity starts at zero, as
        ``MomentumSGD.init`` does."""
        cfg = model.config
        if cfg.impl != "element":
            raise ValueError("XL substrate streams the element (COO) path only")
        spool = Path(spool_dir) if spool_dir is not None else None
        if spool is None and any(
            t.nnz * 4 >= plan.memmap_threshold_bytes for t in model.topos
        ):
            spool = Path(tempfile.mkdtemp(prefix="xl_spool_"))
        layers = []
        for l, topo in enumerate(model.topos):
            thr = plan.memmap_threshold_bytes

            def leaf(a, nm, dtype):
                return host_leaf(
                    np.asarray(a, dtype), threshold_bytes=thr,
                    spool_dir=spool, name=f"l{l}_{nm}",
                )

            layers.append(
                XLLayerState(
                    in_dim=topo.in_dim,
                    out_dim=topo.out_dim,
                    rows=leaf(topo.rows, "rows", np.int32),
                    cols=leaf(topo.cols, "cols", np.int32),
                    perm_r=leaf(
                        element_row_order(topo.rows, topo.cols), "perm_r",
                        np.int64,
                    ),
                    values=leaf(model.values[l], "values", np.float32),
                    velocity=leaf(
                        np.zeros(topo.nnz, np.float32), "velocity", np.float32
                    ),
                    bias=np.asarray(model.biases[l], np.float32).copy(),
                    bias_vel=np.zeros(topo.out_dim, np.float32),
                )
            )
        return cls(
            layer_dims=tuple(cfg.layer_dims),
            activation=cfg.activation,
            alpha=cfg.alpha,
            init=cfg.init,
            layers=layers,
            plan=plan,
            spool_dir=spool,
        )

    def check_invariants(self) -> None:
        for st in self.layers:
            check_element_shards(
                np.asarray(st.rows), np.asarray(st.cols),
                np.asarray(st.perm_r), st.in_dim, st.out_dim,
                self.plan.shard_capacity,
            )

    # -- streamed checkpointing (CheckpointManager.save_streamed) ----------

    def stream_groups(self):
        """``{group: {leaf: (shape, dtype, chunk-iterator)}}`` for
        ``CheckpointManager.save_streamed`` — every iterator yields
        shard-capacity slices, so the writer's working set is one shard no
        matter how large the layer."""
        cap = self.plan.shard_capacity

        def chunks(a):
            def it():
                for lo in range(0, a.shape[0], cap):
                    yield np.asarray(a[lo : lo + cap])
            return (a.shape, a.dtype, it())

        groups = {}
        for l, st in enumerate(self.layers):
            groups[f"xl_layer{l}"] = {
                "rows": chunks(st.rows),
                "cols": chunks(st.cols),
                "perm_r": chunks(st.perm_r),
                "values": chunks(st.values),
                "velocity": chunks(st.velocity),
                "bias": chunks(st.bias),
                "bias_vel": chunks(st.bias_vel),
            }
        return groups

    def save(self, manager, step: int, extra_meta: Optional[dict] = None):
        meta = {
            "kind": "xl_model",
            "layer_dims": list(self.layer_dims),
            "activation": self.activation,
            "alpha": self.alpha,
            "init": self.init,
            "nnz_per_layer": [st.nnz for st in self.layers],
            **(extra_meta or {}),
        }
        manager.save_streamed(step, self.stream_groups(), meta=meta)

    @classmethod
    def restore(
        cls,
        manager,
        plan: XLPlan,
        step: Optional[int] = None,
        spool_dir: Optional[str] = None,
    ) -> "XLModelState":
        """Streamed restore: each leaf is copied shard-by-shard from the
        checkpoint's on-disk memmap into a fresh host leaf."""
        manifest = manager.read_manifest(step)
        meta = manifest["meta"]
        if meta.get("kind") != "xl_model":
            raise ValueError(f"checkpoint is not an xl_model: {meta}")
        spool = Path(spool_dir) if spool_dir is not None else None
        cap = plan.shard_capacity
        layer_dims = tuple(meta["layer_dims"])
        layers = []
        for l in range(len(layer_dims) - 1):
            group = f"xl_layer{l}"

            def leaf(nm):
                src = manager.restore_stream(step, group, nm)
                out = host_leaf(
                    np.empty(src.shape, src.dtype),
                    threshold_bytes=plan.memmap_threshold_bytes,
                    spool_dir=spool, name=f"l{l}_{nm}",
                )
                for lo in range(0, src.shape[0], cap):
                    out[lo : lo + cap] = src[lo : lo + cap]
                return out

            layers.append(
                XLLayerState(
                    in_dim=layer_dims[l],
                    out_dim=layer_dims[l + 1],
                    rows=leaf("rows"), cols=leaf("cols"),
                    perm_r=leaf("perm_r"), values=leaf("values"),
                    velocity=leaf("velocity"), bias=leaf("bias"),
                    bias_vel=leaf("bias_vel"),
                )
            )
        return cls(
            layer_dims=layer_dims,
            activation=meta["activation"],
            alpha=meta["alpha"],
            init=meta["init"],
            layers=layers,
            plan=plan,
            spool_dir=spool,
        )


# ---------------------------------------------------------------------------
# small jitted glue programs (shapes static: one compile each per run)
# ---------------------------------------------------------------------------


def _bias_add_impl(acc, bias_pad):
    return acc + bias_pad[:, None]


# the accumulator is dead after the add (forward() rebinds to z), so donate
# it per the central policy — XLA reuses the (d_max, B) buffer in place
_bias_add = jax.jit(
    _bias_add_impl, donate_argnums=donation.donate_argnums(0)
)


@jax.jit
def _act(z, slope):
    # All-ReLU family: identity above zero, per-parity slope below. Rows
    # beyond the layer's real out_dim are exactly zero and stay zero.
    return jnp.where(z > 0, z, slope * z)


@jax.jit
def _act_bwd(dh, z, slope):
    return dh * jnp.where(z > 0, jnp.ones((), z.dtype), slope)


@jax.jit
def _bias_grad(dz):
    return dz.sum(axis=1)


@functools.partial(jax.jit, static_argnames=("n_classes",))
def _loss_and_dz(zT, labels, *, n_classes: int):
    """CE loss + d(loss)/d(logits), padded back to the (d_max, B) layout.
    Mirrors ``models.mlp.cross_entropy_loss`` exactly (f32 log_softmax,
    mean over the batch)."""
    logits = zT[:n_classes].T.astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None].astype(jnp.int32), axis=-1)
    loss = nll.mean()
    b = logits.shape[0]
    dlogits = (jnp.exp(logp) - jax.nn.one_hot(labels, n_classes)) / b
    dz = jnp.zeros_like(zT).at[:n_classes].set(dlogits.T.astype(zT.dtype))
    return loss, dz


def compile_counts() -> dict:
    """Executable counts of every XL device program — the whole substrate's
    jit surface. Streaming more shards/layers/epochs must not grow any of
    these (asserted in tests and the CI smoke)."""
    return {
        "xl_shard_acc": xl_shard_acc._cache_size(),
        "xl_shard_dw": xl_shard_dw._cache_size(),
        "bias_add": _bias_add._cache_size(),
        "act": _act._cache_size(),
        "act_bwd": _act_bwd._cache_size(),
        "bias_grad": _bias_grad._cache_size(),
        "loss_and_dz": _loss_and_dz._cache_size(),
    }


# ---------------------------------------------------------------------------
# the executor
# ---------------------------------------------------------------------------


def _prefetch(it: Iterator):
    """Double buffering: issue the device_put of item k+1 before yielding
    item k, so the next shard's transfer overlaps the current shard's
    (asynchronously dispatched) compute."""
    it = iter(it)
    try:
        cur = next(it)
    except StopIteration:
        return
    for nxt in it:
        yield cur
        cur = nxt
    yield cur


class StreamExecutor:
    """Runs the streamed forward/backward for one :class:`XLModelState`.

    The executor owns no model state — only the plan-derived static shapes,
    the per-hidden-layer activation slopes and (when the plan marks a layer
    ``topo_resident``) a device cache of its immutable index shards.
    """

    def __init__(self, state: XLModelState):
        self.state = state
        plan = state.plan
        self.plan = plan
        self.d_max = plan.d_max
        self.B = plan.batch
        self.C = plan.shard_capacity
        self.chunk = plan.chunk
        if state.activation not in ("all_relu", "relu", "leaky_relu"):
            raise ValueError(
                f"XL substrate supports piecewise-linear activations with "
                f"f(0)=0, got {state.activation!r}"
            )
        # per hidden layer, the negative-side slope (paper 1-based parity)
        slopes = []
        for l in range(state.n_layers - 1):
            li = l + 1
            if state.activation == "all_relu":
                s = -state.alpha if li % 2 == 0 else state.alpha
            elif state.activation == "relu":
                s = 0.0
            else:
                s = state.alpha
            slopes.append(jnp.float32(s))
        self._slopes = slopes
        self._topo_cache: dict = {}
        self._topo_cache_version = -1
        # device-bytes accounting (see measured_peak_bytes)
        self._measured_peak = 0
        self._sentinel = np.int32(self.d_max)

    # -- device-bytes accounting -------------------------------------------

    def _note_bytes(self, n_buffers: int, extra: int = 0) -> None:
        plan = self.plan
        live = (
            n_buffers * plan.buffer_bytes
            + 2 * self.C * (4 + 8)            # double-buffered shard slots
            + self.C * 4                       # dW output slot
            + 2 * self.chunk * self.B * 4      # chunk slabs
            + 3 * sum(self.state.layer_dims[1:]) * 4
            + self._topo_cache_bytes()
            + extra
        )
        self._measured_peak = max(self._measured_peak, live)

    def _topo_cache_bytes(self) -> int:
        return sum(
            sum(int(a.nbytes) for a in shard)
            for shard in self._topo_cache.values()
        )

    @property
    def measured_peak_bytes(self) -> int:
        """High-water of executor-allocated device bytes, computed from the
        (fully static) shapes of every live buffer at each phase of the
        step — an allocation *audit* of what the executor holds, not a
        runtime allocator probe (CPU jaxlib exposes no device memory
        stats; on accelerators, cross-check against
        ``device.memory_stats()``). XLA's transient chunk temps are
        included via the plan's slab term; the CI smoke compares this
        number against the budget alongside the planner's own estimate."""
        return self._measured_peak

    # -- shard streams ------------------------------------------------------

    def _fwd_host_shards(self, l: int):
        """(bounds, key, values, index-pair-or-None) per canonical shard,
        padded to capacity — cols (the segment ids) pad with the d_max
        sentinel; ``None`` indices mean "device-cached under key"."""
        st = self.state.layers[l]
        for lo, hi in element_shard_bounds(st.nnz, self.C):
            key = ("fwd", l, lo)
            vals = pad_shard(
                np.asarray(st.values[lo:hi], np.float32), self.C, 0.0
            )
            if key in self._topo_cache:
                yield (lo, hi), key, vals, None
            else:
                rows = pad_shard(np.asarray(st.rows[lo:hi]), self.C, 0)
                cols = pad_shard(
                    np.asarray(st.cols[lo:hi]), self.C, self._sentinel
                )
                yield (lo, hi), key, vals, (rows, cols)

    def _dw_host_shards(self, l: int):
        """Index-only canonical shards for the dW pass — ``xl_shard_dw``
        never reads values, so shipping them would be dead transfer volume;
        the cache key is shared with the forward shards (same index
        arrays), so topo_resident layers upload nothing at all here."""
        st = self.state.layers[l]
        for lo, hi in element_shard_bounds(st.nnz, self.C):
            key = ("fwd", l, lo)
            if key in self._topo_cache:
                yield (lo, hi), key, None, None
            else:
                rows = pad_shard(np.asarray(st.rows[lo:hi]), self.C, 0)
                cols = pad_shard(
                    np.asarray(st.cols[lo:hi]), self.C, self._sentinel
                )
                yield (lo, hi), key, None, (rows, cols)

    def _dx_host_shards(self, l: int):
        """Row-order dual shards for dX: values gathered through perm_r on
        the host — rows_r (the segment ids) pad with the sentinel. The
        device order is (gather=cols_r, segment=rows_r)."""
        st = self.state.layers[l]
        for lo, hi in element_shard_bounds(st.nnz, self.C):
            key = ("dx", l, lo)
            p = np.asarray(st.perm_r[lo:hi])
            vals = pad_shard(
                np.asarray(st.values)[p].astype(np.float32, copy=False),
                self.C, 0.0,
            )
            if key in self._topo_cache:
                yield (lo, hi), key, vals, None
            else:
                rows_r = pad_shard(
                    np.asarray(st.rows)[p], self.C, self._sentinel
                )
                cols_r = pad_shard(np.asarray(st.cols)[p], self.C, 0)
                yield (lo, hi), key, vals, (cols_r, rows_r)

    def _device_shards(self, host_iter, cache_layer: bool):
        """device_put each shard one ahead of compute; optionally populate
        the immutable-index device cache (plan: topo_resident). Yields
        ``(bounds, values_dev_or_None, (gather_dev, segment_dev))``."""
        if self._topo_cache_version != self.state.topo_version:
            self._topo_cache.clear()
            self._topo_cache_version = self.state.topo_version

        def upload():
            for bounds, key, vals, idx in host_iter:
                if idx is None:
                    idx_dev = self._topo_cache[key]
                else:
                    idx_dev = jax.device_put(idx)
                    if cache_layer:
                        self._topo_cache[key] = idx_dev
                vals_dev = None if vals is None else jax.device_put(vals)
                yield bounds, vals_dev, idx_dev

        return _prefetch(upload())

    def _layer_resident(self, l: int) -> bool:
        return self.plan.layers[l].topo_resident

    # -- forward ------------------------------------------------------------

    def _pad_input(self, xb: np.ndarray) -> jax.Array:
        """(B', n_feat) host batch -> (d_max, B) transposed device buffer;
        ragged eval tails zero-pad the batch axis."""
        if xb.shape[0] > self.B:
            raise ValueError(
                f"batch of {xb.shape[0]} exceeds the plan's batch {self.B}"
            )
        xT = np.zeros((self.d_max, self.B), np.float32)
        xT[: xb.shape[1], : xb.shape[0]] = np.asarray(xb, np.float32).T
        return jax.device_put(xT)

    def _stream_matmul(self, l: int, srcT, shards) -> jax.Array:
        acc = jnp.zeros((self.d_max, self.B), jnp.float32)
        for _, vals, (gather, segment) in shards:
            acc = xl_shard_acc(
                acc, srcT, vals, gather, segment,
                n_segments=self.d_max, chunk=self.chunk,
            )
        return acc

    def _bias_pad(self, l: int) -> jax.Array:
        st = self.state.layers[l]
        b = np.zeros((self.d_max,), np.float32)
        b[: st.out_dim] = st.bias
        return jax.device_put(b)

    def forward(self, xb: np.ndarray, *, keep_preacts: bool):
        """Streamed forward. Returns (logitsT-as-z buffer, x_dev, [z per
        layer]); with ``keep_preacts=False`` only the final z survives."""
        n = self.state.n_layers
        # one span per streamed forward, NOT per shard — the shard loop is
        # the substrate's hot path and its dispatches are async; nothing is
        # registered on the span, so it measures enqueue, not device time
        with obs.span("xl.forward", layers=n):
            x_dev = self._pad_input(xb)
            h = x_dev
            zs: List[jax.Array] = []
            for l in range(n):
                shards = self._device_shards(
                    self._fwd_host_shards(l), self._layer_resident(l)
                )
                acc = self._stream_matmul(l, h, shards)
                z = _bias_add(acc, self._bias_pad(l))
                if keep_preacts:
                    zs.append(z)
                if l < n - 1:
                    h = _act(z, self._slopes[l])
                else:
                    h = z
            self._note_bytes((len(zs) if keep_preacts else 1) + 3)
            return h, x_dev, zs

    def logits(self, xb: np.ndarray) -> np.ndarray:
        """Streamed inference logits for up to ``plan.batch`` rows."""
        z, _, _ = self.forward(xb, keep_preacts=False)
        n_out = self.state.layer_dims[-1]
        return np.asarray(z)[:n_out, : xb.shape[0]].T

    # -- train step ---------------------------------------------------------

    def train_step(self, xb: np.ndarray, yb: np.ndarray, lr: float,
                   *, momentum: float, weight_decay: float):
        """One streamed minibatch step: forward, CE loss, streamed backward
        with immediate per-shard host momentum-SGD updates. Semantically the
        in-core ``launch.steps.make_mlp_step_core`` (same loss, same update
        order: all gradients are taken against pre-update parameters)."""
        st = self.state
        n = st.n_layers
        if xb.shape[0] != self.B:
            raise ValueError(
                f"train_step needs a full batch of {self.B} rows, got "
                f"{xb.shape[0]} — the loss/gradient programs are shaped for "
                f"the plan's batch (ragged batches are eval-only)"
            )
        mu, wd = np.float32(momentum), np.float32(weight_decay)
        lr = np.float32(lr)
        # the step ends with float(loss) — fully synced, so span close
        # needs no block_on
        with obs.span("xl.train_step"):
            return self._train_step_inner(xb, yb, lr, mu, wd)

    def _train_step_inner(self, xb, yb, lr, mu, wd):
        st = self.state
        n = st.n_layers
        _, x_dev, zs = self.forward(xb, keep_preacts=True)
        y_dev = jax.device_put(np.asarray(yb, np.int32))
        loss, dz = _loss_and_dz(zs[-1], y_dev, n_classes=st.layer_dims[-1])
        for l in range(n - 1, -1, -1):
            layer = st.layers[l]
            # bias update (gradient against pre-update bias, like in-core)
            db = np.asarray(_bias_grad(dz))[: layer.out_dim]
            g = db + wd * layer.bias
            layer.bias_vel[:] = mu * layer.bias_vel - lr * g
            layer.bias += layer.bias_vel
            # dX first: it reads the layer's *pre-update* values
            if l > 0:
                shards = self._device_shards(
                    self._dx_host_shards(l), self._layer_resident(l)
                )
                dh = self._stream_matmul(l, dz, shards)
            h_prev = x_dev if l == 0 else _act(zs[l - 1], self._slopes[l - 1])
            # dW + host update, shard by shard (index-only stream: dW never
            # reads the values, the host update does that in place)
            shards = self._device_shards(
                self._dw_host_shards(l), self._layer_resident(l)
            )
            for (lo, hi), _, (rows, cols) in shards:
                dv = xl_shard_dw(h_prev, dz, rows, cols, chunk=self.chunk)
                dv_np = np.asarray(dv)[: hi - lo]
                v = layer.values[lo:hi]
                gsl = dv_np + wd * v
                layer.velocity[lo:hi] = mu * layer.velocity[lo:hi] - lr * gsl
                layer.values[lo:hi] = v + layer.velocity[lo:hi]
            if l > 0:
                dz = _act_bwd(dh, zs[l - 1], self._slopes[l - 1])
        self._note_bytes(n + 5)
        return float(loss)

    # -- training-dynamics probe (obs.probes, DESIGN.md §12) -----------------

    def probe_stats(self, xb: np.ndarray, yb: np.ndarray) -> List[dict]:
        """Per-layer training-dynamics stats for one (full) batch.

        Device side reuses the substrate's existing programs only — a
        ``keep_preacts`` forward, ``_loss_and_dz`` and a dX/act-backward
        walk — plus ``probes.padded_buffer_probe`` (one extra jitted
        reduction per (d_max, B) shape, pinned via
        ``probes.probe_compile_counts``). No whole-layer dW is ever
        materialized, so ``grad_l2`` here is the *pre-activation* gradient
        norm (the dz buffer), a parameter-gradient proxy. Value magnitude
        and neuron-importance stats come from streamed host passes over the
        pinned leaves (``probes.streamed_*`` — one shard-sized working set).

        Returns a list of per-layer stat dicts ready for
        ``probes.record_snapshot(..., layers=...)``.
        """
        st = self.state
        n = st.n_layers
        if xb.shape[0] != self.B:
            raise ValueError(
                f"probe_stats needs a full batch of {self.B} rows, got "
                f"{xb.shape[0]} — padded batch columns would pollute the "
                f"saturation/gradient reductions"
            )
        with obs.span("xl.probe", layers=n):
            _, x_dev, zs = self.forward(xb, keep_preacts=True)
            y_dev = jax.device_put(np.asarray(yb, np.int32))
            _, dz = _loss_and_dz(zs[-1], y_dev, n_classes=st.layer_dims[-1])
            dzs: List[jax.Array] = [None] * n
            dzs[n - 1] = dz
            for l in range(n - 1, 0, -1):
                shards = self._device_shards(
                    self._dx_host_shards(l), self._layer_resident(l)
                )
                dh = self._stream_matmul(l, dzs[l], shards)
                dzs[l - 1] = _act_bwd(dh, zs[l - 1], self._slopes[l - 1])
            dev = []
            for l in range(n):
                out_dim = st.layers[l].out_dim
                sat, z_l2, _ = probes.padded_buffer_probe(zs[l], out_dim)
                _, g_l2, g_zero = probes.padded_buffer_probe(dzs[l], out_dim)
                dev.append((sat, z_l2, g_l2, g_zero))
            jax.block_until_ready(dev)
            self._note_bytes(2 * n + 3)
        layers = []
        for l in range(n):
            layer = st.layers[l]
            sat, z_l2, g_l2, g_zero = (float(np.asarray(a)) for a in dev[l])
            row = {
                "saturation": sat,
                "preact_l2": z_l2,
                "grad_l2": g_l2,
                "grad_zero_frac": g_zero,
            }
            row.update(probes.streamed_value_stats(layer.values))
            row.update(
                probes.streamed_importance_quantiles(
                    layer.values, layer.cols, layer.out_dim
                )
            )
            layers.append(row)
        return layers


# ---------------------------------------------------------------------------
# contract auditor registration (repro.analysis, DESIGN.md §10)
# ---------------------------------------------------------------------------


def analysis_programs():
    """Registry hook: the two streamed shard programs — the ONLY device
    matmuls the XL substrate ever dispatches. Audit scale: d_max=32, B=8,
    one 128-slot shard of 64-wide chunks (shapes are arbitrary here; the
    contracts are structural)."""
    from repro.analysis.registry import AuditProgram, Contract, ProgramSpec

    d_max, B, cap, chunk = 32, 8, 128, 64

    def build_acc() -> AuditProgram:
        idx = jnp.arange(cap, dtype=jnp.int32)
        args = (
            jnp.zeros((d_max, B), jnp.float32),       # acc (donated)
            jnp.zeros((d_max, B), jnp.float32),       # srcT
            jnp.zeros((cap,), jnp.float32),           # values
            idx % d_max,                              # gather_idx
            jnp.sort(idx % d_max),                    # segment_idx (sorted)
        )
        return AuditProgram(
            make=lambda donate: make_xl_shard_acc(donate=donate),
            args=args,
            kwargs={"n_segments": d_max, "chunk": chunk},
            meta={"d_max": d_max, "batch": B, "capacity": cap},
        )

    def build_dw() -> AuditProgram:
        idx = jnp.arange(cap, dtype=jnp.int32)
        args = (
            jnp.zeros((d_max, B), jnp.float32),       # xT
            jnp.zeros((d_max, B), jnp.float32),       # dyT
            idx % d_max,                              # rows
            jnp.sort(idx % d_max),                    # cols
        )
        return AuditProgram(
            make=lambda donate: make_xl_shard_dw(donate=donate),
            args=args,
            kwargs={"chunk": chunk},
            meta={"d_max": d_max, "batch": B, "capacity": cap},
        )

    shard_contract = dict(
        # sorted segment-sum only: ZERO unsorted scatters anywhere in the
        # streamed substrate, forward or backward
        max_unsorted_scatter=0,
        max_intermediate_elems=4 * chunk * B,
        max_temp_bytes=1024 * 1024,
        expected_compiles=1,
    )
    return [
        ProgramSpec(
            name="xl.shard_acc",
            subsystem=__name__,
            contract=Contract(donate_argnums=(0,), **shard_contract),
            build=build_acc,
            notes="one program for streamed fwd AND dX; acc donated",
        ),
        ProgramSpec(
            name="xl.shard_dw",
            subsystem=__name__,
            contract=Contract(**shard_contract),
            build=build_dw,
            notes="per-shard dW batch contraction; all inputs reused",
        ),
    ]
